"""The rewrite passes: each is ``Plan -> Plan`` with a provenance trail.

The rewrite-pass contract (DESIGN.md §11): **every pass preserves
bit-for-bit published-table semantics** — values, validity masks, row
order, NULL fills. The proof obligation is the differential suite
(``tests/test_optimizer_differential.py``: every fixture pipeline runs
optimized and unoptimized across every registered backend and the
published snapshots must fingerprint identically); the arguments for
*why* each rewrite is safe live on the passes below and in DESIGN.md.
A pass that cannot prove a rewrite applies leaves the tree alone —
opaque expressions (``Expr.references() is None``), non-inner joins
where the rewrite needs inner semantics, missing statistics: all are
"don't rewrite", never "rewrite and hope".

Shared soundness inputs:

- **left-copy-wins**: a join output takes name-shadowed columns from
  the left side (``_gather_right`` skips names already present), which
  is what makes left-pushes and keep-everywhere pruning order-safe;
- **declared schemas**: pushdown/pruning reason over contract-declared
  column sets. The documented conformance caveat: physical tables may
  carry *extra* undeclared columns, and the passes assume those extras
  never shadow a declared column of the other join side (an undeclared
  left column named like a declared right column would flip a
  right-push's copy source). Steps whose output is a projection are
  immune — extras never reach their published output;
- **contract reference sets** (:func:`repro.core.contracts.referenced_columns`):
  the Appendix-A elision condition — a source column may only be
  elided when no contract verifier and no downstream reference needs
  it.

Float-SUM carve-out: the backends' one cross-backend tolerance is
float SUM/MEAN summation order. No *restructuring* pass reorders an
aggregation — pushdown/reorder/pruning/fusion touch scans, filters,
projections and joins, all of which gather rows rather than summing
(filter-below-Aggregate preserves every surviving group's row set
exactly) — so their optimized-vs-unoptimized equality is exact, not
tolerance-based. The one exception is ``partial_agg``, which is
physical routing: it changes *where* an aggregation runs (the sharded
backend's per-shard partials), which regroups float sums within the
documented carve-out; integer aggregates remain bit-for-bit, and the
strategy renders in ``describe()`` so the cache key moves with it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core import planner as P
from repro.core import schema as S
from repro.core.contracts import (check_node, provable_postconditions,
                                  referenced_columns)
from repro.core.dag import DeclarativeNode
from repro.core.logical import (Aggregate, Filter, Join, Limit,
                                LogicalOp, Project, Reorder, Scan, Sort)

__all__ = ["DEFAULT_PASSES", "PASSES", "optimize",
           "filter_pushdown", "join_reorder", "column_pruning",
           "probe_fusion", "partial_agg"]

# Selectivity assumed for a filtered side when ordering joins — a
# cost-model constant, not semantics (a bad estimate costs time, never
# correctness: the reorder is bit-for-bit by construction).
DEFAULT_FILTER_SELECTIVITY = 0.33


# ---------------------------------------------------------------------------
# tree helpers
# ---------------------------------------------------------------------------
# NOTE: never compare ops or exprs with `==` — Expr overloads equality
# to BUILD expressions. Identity of a subtree is its describe() string
# (total and structural, the same property cache keys rely on).

def _walk(op: LogicalOp):
    yield op
    for c in op.children():
        yield from _walk(c)


def _map_children(op: LogicalOp,
                  fn: Callable[[LogicalOp], LogicalOp]) -> LogicalOp:
    if isinstance(op, (Filter, Project, Aggregate, Sort, Limit)):
        return dataclasses.replace(op, child=fn(op.child))
    if isinstance(op, Join):
        return dataclasses.replace(op, left=fn(op.left),
                                   right=fn(op.right))
    if isinstance(op, Reorder):
        return dataclasses.replace(
            op, base=fn(op.base),
            sides=tuple((fn(s), on) for s, on in op.sides))
    return op


def _schemas(plan: P.Plan) -> dict[str, type[S.Schema]]:
    out: dict[str, type[S.Schema]] = dict(plan.source_schemas)
    for s in plan.steps:
        out[s.node.name] = s.node.output_schema
    return out


def _op_cols(op: LogicalOp, schemas: Mapping[str, type[S.Schema]]
             ) -> set[str] | None:
    """Declared output-column set of a subtree; None = unknown."""
    if isinstance(op, Scan):
        if op.table not in schemas:
            return None
        cols = set(schemas[op.table].names())
        if op.columns is not None:
            cols &= set(op.columns)
        return cols
    if isinstance(op, (Filter, Sort, Limit)):
        return _op_cols(op.child, schemas)
    if isinstance(op, Project):
        return {e.output_name() for e in op.exprs}
    if isinstance(op, Aggregate):
        return set(op.keys) | {out for _fn, _value, out in op.specs}
    if isinstance(op, (Join, Reorder)):
        acc: set[str] = set()
        for c in op.children():
            sub = _op_cols(c, schemas)
            if sub is None:
                return None
            acc |= sub
        return acc
    return None


def _tree_refs(op: LogicalOp) -> set[str] | None:
    """Every input-column name any expression or join key in the tree
    reads; None if any expression is opaque (unknown reads)."""
    refs: set[str] = set()
    for node in _walk(op):
        if isinstance(node, Join):
            refs |= set(node.on)
        if isinstance(node, Reorder):
            for _, on in node.sides:
                refs |= set(on)
        if isinstance(node, Aggregate):
            refs |= set(node.keys)
            refs |= {value for _fn, value, _out in node.specs}
        if isinstance(node, Sort):
            # sort keys name OUTPUT columns of the op below (usually a
            # Project); folding them into the reference set is
            # conservative — it can only keep more source columns alive.
            refs |= {name for name, _asc in node.keys}
        for e in node._own_exprs():
            r = e.references()
            if r is None:
                return None
            refs |= r
        if isinstance(node, Project):
            for e in node.exprs:
                r = e.references()
                if r is None:
                    return None
                refs |= r
    return refs


# ---------------------------------------------------------------------------
# pass: filter pushdown (+ shared-filter materialization)
# ---------------------------------------------------------------------------

def filter_pushdown(plan: P.Plan) -> P.Plan:
    """Push ``Filter`` below ``Join`` where the predicate provably
    reads one side, then hoist filters that now appear identically in
    several steps into one shared auxiliary (unpublished) step.

    Left-push (``refs ⊆ left cols``; inner or left join): the joined
    value of every referenced name is the LEFT copy (left-copy-wins),
    so the predicate sees identical values above and below; filtering
    left rows before the join drops exactly the rows whose every
    emitted copy the post-join filter would drop, in the same order.
    Valid for left joins too — an unmatched left row's referenced
    values are its own.

    Right-push (``refs ⊆ right cols`` and ``refs ∩ left cols ⊆ on``;
    inner only): any referenced name also present on the left must be
    a join key, where matched rows guarantee left copy == right copy;
    purely-right names reach the output from the right side. Dropping
    right rows pre-join removes exactly the match pairs the post-join
    filter would drop. Not valid for left joins (a dropped right row
    must yield an unmatched NULL-filled emission, not a dropped one).

    Aggregate-push (``refs ⊆ group keys``, non-float key dtypes): an
    output row's key columns hold its group's key values, and every
    row of a group carries an equal key value, so a key-only predicate
    decides identically for a group above the ``Aggregate`` and for
    each of the group's rows below it — surviving groups keep exactly
    their original row sets (aggregates and summation order unchanged)
    in first-appearance order, and the NULL-keyed group behaves the
    same way because a NULL predicate input drops the row on both
    sides. The dtype guard is load-bearing: *float* keys group
    value-equal but bit-distinct representatives (``-0.0 == 0.0``),
    which an arithmetic predicate (``1/k > 0``) can tell apart — a
    per-row push could then keep a different representative (or a
    group the post-aggregation filter dropped), so float-keyed
    predicates stay above.
    """
    schemas = _schemas(plan)
    pushed: set[str] = set()

    def push(op: LogicalOp) -> LogicalOp:
        if isinstance(op, Filter):
            child = push(op.child)
            return sink(op.pred, child)
        return _map_children(op, push)

    def sink(pred, op: LogicalOp) -> LogicalOp:
        refs = pred.references()
        if (refs is not None and isinstance(op, Join)
                and op.left_pred is None and op.right_pred is None):
            lcols = _op_cols(op.left, schemas)
            rcols = _op_cols(op.right, schemas)
            if lcols is not None and rcols is not None:
                if refs <= lcols and op.how in ("inner", "left"):
                    pushed.add("join")
                    return dataclasses.replace(
                        op, left=sink(pred, op.left))
                if (op.how == "inner" and refs <= rcols
                        and refs & lcols <= set(op.on)):
                    pushed.add("join")
                    return dataclasses.replace(
                        op, right=sink(pred, op.right))
        if (refs is not None and isinstance(op, Aggregate)
                and refs <= set(op.keys)
                and _agg_keys_pushable(refs, op.child, schemas)):
            pushed.add("aggregate")
            return dataclasses.replace(op, child=sink(pred, op.child))
        return Filter(op, pred)

    new_steps: list[P.PlanStep] = []
    for step in plan.steps:
        if step.logical is None:
            new_steps.append(step)
            continue
        pushed.clear()
        tree = push(step.logical)
        if tree.describe() != step.logical.describe():
            what = " and ".join(sorted(pushed)) or "join"
            step = dataclasses.replace(
                step, logical=tree,
                provenance=step.provenance
                + (f"filter_pushdown: pushed filter below {what}",))
        new_steps.append(step)

    return _materialize_shared_filters(plan, new_steps, schemas)


def _agg_keys_pushable(refs: set[str], child: LogicalOp,
                       schemas) -> bool:
    """True iff every referenced group key resolves to a declared
    non-float column below the Aggregate (the value-determined-
    representative condition of the aggregate push: int/bool/str/
    datetime equality implies bit-identical payloads, float does not)."""
    for name in refs:
        families = {
            schemas[node.table].columns()[name].dtype.family
            for node in _walk(child)
            if isinstance(node, Scan) and node.table in schemas
            and name in schemas[node.table].columns()
            and (node.columns is None or name in node.columns)}
        if not families or "float" in families:
            return False
    return True


def _materialize_shared_filters(plan: P.Plan,
                                steps: list[P.PlanStep],
                                schemas) -> P.Plan:
    """Hoist a ``Filter(Scan(t), pred)`` subtree appearing (by
    structural description) in two or more places into ONE unpublished
    auxiliary step, so the filter runs once instead of per consumer.
    Sound trivially — consumers read a materialization of the exact
    subtree they contained — but it *moves waves*: consumers gain a
    dependency level, which is why :func:`repro.core.planner.rebuild`
    recomputes wave numbering after every pass."""
    counts: dict[str, tuple] = {}
    for step in steps:
        if step.logical is None:
            continue
        for node in _walk(step.logical):
            if (isinstance(node, Filter)
                    and isinstance(node.child, Scan)
                    and node.child.columns is None
                    and node.child.table in schemas
                    and getattr(node.pred, "_structural", False)
                    and node.pred.references() is not None):
                d = node.describe()
                n, _ = counts.get(d, (0, None))
                counts[d] = (n + 1, node)
    shared = {d: node for d, (n, node) in counts.items() if n >= 2}
    if not shared:
        return P.rebuild(plan, steps)

    used = {s.node.name for s in steps} | set(plan.source_schemas)
    out: list[P.PlanStep] = list(steps)
    aux_i = 0
    for desc, subtree in sorted(shared.items()):
        table = subtree.child.table
        schema = schemas[table]
        while f"__opt_shared_{aux_i}" in used:
            aux_i += 1
        aux_name = f"__opt_shared_{aux_i}"
        used.add(aux_name)

        def replace(op: LogicalOp) -> LogicalOp:
            if op.describe() == desc:
                return Scan(aux_name)
            return _map_children(op, replace)

        first_consumer = None
        stats = None
        for i, step in enumerate(out):
            if step.logical is None:
                continue
            tree = replace(step.logical)
            if tree.describe() == step.logical.describe():
                continue
            if first_consumer is None:
                first_consumer = i
                if step.input_stats and table in step.input_stats:
                    stats = {table: step.input_stats[table]}
            tabs = sorted(tree.scan_tables())
            node = dataclasses.replace(
                step.node,
                inputs={t: t for t in tabs},
                input_schemas={t: (schema if t == aux_name
                                   else schemas[t]) for t in tabs})
            out[i] = dataclasses.replace(
                step, node=node, logical=tree,
                provenance=step.provenance
                + (f"filter_pushdown: shared filter on {table!r} "
                   f"materialized as {aux_name!r}",))
        if first_consumer is None:     # pragma: no cover - defensive
            continue
        aux_node = DeclarativeNode(
            name=aux_name, inputs={table: table},
            input_schemas={table: schema}, output_schema=schema,
            filter_expr=subtree.pred)
        aux_step = P.PlanStep(
            node=aux_node,
            report=check_node({table: schema}, schema),
            elided_null_checks=provable_postconditions(
                {table: schema}, schema, inspectable=True,
                null_preserving=True),
            input_stats=stats,
            logical=Filter(Scan(table), subtree.pred),
            published=False,
            provenance=(f"filter_pushdown: materialized shared "
                        f"filter {desc}",))
        out.insert(first_consumer, aux_step)
        schemas[aux_name] = schema
    return P.rebuild(plan, out)


# ---------------------------------------------------------------------------
# pass: join reordering (cardinality-driven)
# ---------------------------------------------------------------------------

def join_reorder(plan: P.Plan) -> P.Plan:
    """Reorder an all-inner left-deep join chain to probe estimated-
    small sides first, wrapped in :class:`Reorder` so the original
    row/column order is restored — the rewrite is bit-for-bit by
    construction, the estimates only pick which order to *execute*.

    Requirements (else leave alone): >= 2 sides; every base/side is a
    ``Scan`` or ``Filter(Scan)``; planner ``TableStats`` present for
    every side's table; pairwise-disjoint declared side column sets
    (base overlap is fine — base stays leftmost, so its copies win in
    every order). Greedy order: repeatedly take the smallest-estimate
    side whose join keys are all available; the smallest-index
    unordered side is always eligible, so the greedy never deadlocks.
    """
    schemas = _schemas(plan)
    new_steps: list[P.PlanStep] = []
    for step in plan.steps:
        rewritten = (_reorder_tree(step, schemas)
                     if step.logical is not None else None)
        if rewritten is None:
            new_steps.append(step)
        else:
            tree, msg = rewritten
            new_steps.append(dataclasses.replace(
                step, logical=tree,
                provenance=step.provenance + (msg,)))
    return P.rebuild(plan, new_steps)


def _reorder_tree(step: P.PlanStep, schemas):
    # peel Project/Filter/Aggregate/Sort/Limit wrappers down to the
    # join chain root (Reorder restores exact row order, so any
    # row-order-sensitive op above it — an Aggregate's groups,
    # representatives and summation order, a Sort's tiebreaks, a
    # Limit's prefix — sees identical input)
    wrappers: list[LogicalOp] = []
    op = step.logical
    while isinstance(op, (Project, Filter, Aggregate, Sort, Limit)):
        wrappers.append(op)
        op = op.child
    if not isinstance(op, Join):
        return None
    sides: list[tuple[LogicalOp, tuple[str, ...]]] = []
    cur: LogicalOp = op
    while (isinstance(cur, Join) and cur.how == "inner"
           and cur.left_pred is None and cur.right_pred is None):
        sides.append((cur.right, cur.on))
        cur = cur.left
    base = cur
    sides.reverse()
    if len(sides) < 2 or isinstance(base, Join):
        return None

    def scan_of(side: LogicalOp):
        if isinstance(side, Scan):
            return side, 1.0
        if isinstance(side, Filter) and isinstance(side.child, Scan):
            return side.child, DEFAULT_FILTER_SELECTIVITY
        return None, 0.0

    base_scan, _ = scan_of(base)
    if base_scan is None:
        return None
    stats = step.input_stats or {}
    ests: list[float] = []
    side_cols: list[set[str]] = []
    for side, _on in sides:
        scan, sel = scan_of(side)
        if scan is None or scan.table not in stats:
            return None
        st = stats[scan.table]
        n = getattr(st, "n_rows", None)
        if n is None:
            return None
        ests.append(n * sel)
        cols = _op_cols(side, schemas)
        if cols is None:
            return None
        side_cols.append(cols)
    for i in range(len(sides)):
        for j in range(i + 1, len(sides)):
            if side_cols[i] & side_cols[j]:
                return None              # shadowing would depend on order
    base_cols = _op_cols(base, schemas)
    if base_cols is None:
        return None

    available = set(base_cols)
    remaining = list(range(len(sides)))
    order: list[int] = []
    while remaining:
        ready = [k for k in remaining if set(sides[k][1]) <= available]
        k = min(ready, key=lambda k: (ests[k], k))
        order.append(k)
        remaining.remove(k)
        available |= side_cols[k]
    if order == sorted(order):
        return None                      # already cheapest-first

    tree: LogicalOp = Reorder(base=base, sides=tuple(sides),
                              order=tuple(order))
    for w in reversed(wrappers):
        tree = dataclasses.replace(w, child=tree)
    est_txt = ", ".join(f"{i}:{e:.0f}" for i, e in enumerate(ests))
    return tree, (f"join_reorder: order={order} by estimated rows "
                  f"[{est_txt}]")


# ---------------------------------------------------------------------------
# pass: dead-column elision (projection pushdown)
# ---------------------------------------------------------------------------

def column_pruning(plan: P.Plan) -> P.Plan:
    """Elide source columns no expression, join key, contract verifier
    or downstream consumer references (Appendix-A elision soundness).

    Applies only to steps whose tree root is a ``Project`` or an
    ``Aggregate`` — their published output is exactly the projected
    (resp. keys + aggregate) columns, so mid-tree column sets are
    unobservable and pruning cannot change the output
    ... with one structural caveat handled by *keep-everywhere*: a
    needed name present in several scans must stay in ALL of them, or
    left-copy-wins would resolve it to a different copy. The keep set
    is therefore global per step: every tree reference + every column
    the output contract resolves to an input (the verifier's reach);
    every scan keeps exactly its intersection with that set.

    Second phase: an *auxiliary* (unpublished) step's output schema may
    itself shrink when every downstream scan of it is pruned — the "no
    downstream step references it" half of the elision condition;
    verifiers only ever attach to published tables, so the contract
    half is vacuous for aux steps.
    """
    schemas = _schemas(plan)
    new_steps: list[P.PlanStep] = []
    for step in plan.steps:
        pruned = (_prune_step(step, schemas)
                  if step.logical is not None else None)
        if pruned is None:
            new_steps.append(step)
        else:
            tree, msg = pruned
            new_steps.append(dataclasses.replace(
                step, logical=tree,
                provenance=step.provenance + (msg,)))
    new_steps = _prune_aux_outputs(new_steps, schemas)
    return P.rebuild(plan, new_steps)


def _prune_step(step: P.PlanStep, schemas):
    tree = step.logical
    # an Aggregate root is as prunable as a Project root: its output
    # is exactly keys + spec outputs, so mid-tree column sets are just
    # as unobservable. Sort/Limit wrappers above such a root are
    # column-transparent (pure row selection/permutation), so peel them
    # when testing the shape — the prune itself rewrites scans only.
    root = tree
    while isinstance(root, (Sort, Limit)):
        root = root.child
    if not isinstance(root, (Project, Aggregate)):
        return None
    needed = _tree_refs(tree)
    if needed is None:
        return None                      # opaque expression somewhere
    inputs = {t: schemas[t] for t in set(step.node.inputs.values())
              if t in schemas}
    computed: set[str] = set()
    if isinstance(step.node, DeclarativeNode) and step.node.agg_specs:
        computed = {out for _fn, _value, out in step.node.agg_specs}
    contract = referenced_columns(inputs, step.node.output_schema,
                                  computed=computed)
    keep = set(needed)
    for cols in contract.values():
        keep |= cols
    # names in the keep set that no input DECLARES may still exist
    # physically (the conformance caveat allows extras) — every scan
    # must keep them; declared names keep per-scan intersection.
    all_declared: set[str] = set()
    for node in _walk(tree):
        if isinstance(node, Scan) and node.table in schemas:
            all_declared |= set(schemas[node.table].names())
    extras = keep - all_declared
    elided: dict[str, list[str]] = {}

    def prune(op: LogicalOp) -> LogicalOp:
        if isinstance(op, Scan) and op.columns is None \
                and op.table in schemas:
            declared = set(schemas[op.table].names())
            drop = sorted(declared - keep)
            if drop:
                elided[op.table] = drop
                return Scan(op.table,
                            columns=tuple(sorted((keep & declared)
                                                 | extras)))
            return op
        return _map_children(op, prune)

    new_tree = prune(tree)
    if not elided:
        return None
    msg = "; ".join(f"{t}: -{cols}" for t, cols in sorted(elided.items()))
    return new_tree, (f"column_pruning: elided unreferenced source "
                      f"columns ({msg})")


def _prune_aux_outputs(steps: list[P.PlanStep], schemas):
    out = list(steps)
    for i, step in enumerate(out):
        if step.published or not isinstance(step.node, DeclarativeNode):
            continue
        name = step.node.name
        consumed: set[str] = set()
        consumers = []
        prunable = True
        for j, other in enumerate(out):
            if j == i or name not in set(other.node.inputs.values()):
                continue
            consumers.append(j)
            if other.logical is None:
                prunable = False
                break
            for node in _walk(other.logical):
                if isinstance(node, Scan) and node.table == name:
                    if node.columns is None:
                        prunable = False
                        break
                    consumed |= set(node.columns)
            if not prunable:
                break
        if not prunable or not consumers:
            continue
        own = _tree_refs(step.logical) if step.logical is not None \
            else None
        if own is None:
            continue
        keep = consumed | own
        declared = step.node.output_schema.columns()
        drop = sorted(set(declared) - keep)
        if not drop:
            continue
        kept_cols = {n: c for n, c in declared.items() if n in keep}
        pruned_schema = S.Schema.of(
            f"{step.node.output_schema.__name__}Pruned", **kept_cols)
        # shrink the aux's own scan too: the dropped columns are never
        # read by anyone, so they need not even be materialized.
        def shrink(op: LogicalOp) -> LogicalOp:
            if isinstance(op, Scan) and op.columns is None:
                return Scan(op.table, columns=tuple(sorted(keep)))
            return _map_children(op, shrink)

        in_schemas = {t: schemas[t]
                      for t in set(step.node.inputs.values())
                      if t in schemas}
        node = dataclasses.replace(step.node,
                                   output_schema=pruned_schema)
        out[i] = dataclasses.replace(
            step, node=node,
            logical=shrink(step.logical),
            report=check_node(in_schemas, pruned_schema,
                              casts=step.node.casts),
            elided_null_checks=provable_postconditions(
                in_schemas, pruned_schema, inspectable=True,
                null_preserving=step.node.null_preserving),
            provenance=step.provenance
            + (f"column_pruning: aux output pruned to {sorted(keep)} "
               f"— no downstream step or contract verifier references "
               f"{drop}",))
        schemas[name] = pruned_schema
        for j in consumers:
            other = out[j]
            out[j] = dataclasses.replace(
                other, node=dataclasses.replace(
                    other.node,
                    input_schemas={
                        t: (pruned_schema if t == name else sch)
                        for t, sch in other.node.input_schemas.items()
                    }))
    return out


# ---------------------------------------------------------------------------
# pass: probe fusion (filter_select fused into the join probe)
# ---------------------------------------------------------------------------

def probe_fusion(plan: P.Plan) -> P.Plan:
    """Fuse a ``Filter`` feeding a ``Join`` into the join's masked
    probe (``Backend.masked_hash_join``): the predicate mask travels
    into the probe, so the filtered intermediate is never
    materialized — on the Pallas path the filtered rows never leave
    VMEM. Semantically the identity rewrite: ``masked_hash_join`` is
    *defined* as filter-then-join (base.py), which is exactly the tree
    being replaced. Left-side fusion only under inner joins (backends
    would prefilter for left joins anyway — no fusion win); right-side
    fusion under inner and left joins. Chained filters compose with
    ``&`` (same mask: SQL NULL-drop distributes over conjunction).
    """
    fused = [0]

    def fuse(op: LogicalOp) -> LogicalOp:
        op = _map_children(op, fuse)
        if not isinstance(op, Join):
            return op
        left, right = op.left, op.right
        lp, rp = op.left_pred, op.right_pred
        if op.how == "inner":
            while isinstance(left, Filter):
                lp = left.pred if lp is None else (left.pred & lp)
                left = left.child
        while isinstance(right, Filter):
            rp = right.pred if rp is None else (right.pred & rp)
            right = right.child
        if lp is op.left_pred and rp is op.right_pred:
            return op
        fused[0] += 1
        return dataclasses.replace(op, left=left, right=right,
                                   left_pred=lp, right_pred=rp)

    new_steps: list[P.PlanStep] = []
    for step in plan.steps:
        if step.logical is None:
            new_steps.append(step)
            continue
        fused[0] = 0
        tree = fuse(step.logical)
        if fused[0]:
            step = dataclasses.replace(
                step, logical=tree,
                provenance=step.provenance
                + (f"probe_fusion: fused {fused[0]} filter(s) into "
                   f"join probe masks",))
        new_steps.append(step)
    return P.rebuild(plan, new_steps)


# ---------------------------------------------------------------------------
# pass: mesh-sharded partial aggregation
# ---------------------------------------------------------------------------

def partial_agg(plan: P.Plan) -> P.Plan:
    """Route large single-int-key ``Aggregate`` ops through the sharded
    backend's pre-exchange partial aggregation
    (``Aggregate.strategy="partial"``).

    A physical-routing rewrite, not a tree restructuring: every
    strategy computes the same table, and the sharded backend
    re-validates its own preconditions at dispatch (degrading to the
    inherited path when the data disagrees with the plan-time stats),
    so a stale estimate costs time, never correctness. The one
    observable difference is the documented float-SUM/MEAN
    summation-order carve-out — which is exactly why a non-default
    strategy renders in ``describe()`` and therefore moves the step's
    cache key; integer aggregates stay bit-for-bit and the
    differential suite pins them exactly.

    Gate (all must hold, read at optimize time): plan-time stats show
    ``n_rows >= repro.exec.auto.SHARD_ROWS`` for the aggregate's one
    source table; the mesh has more than one device; the sharded
    backend is importable; the single group key is declared with an
    integer dtype by that source (the dense-rebase partial path only
    handles int keys — anything else would just flip the strategy and
    fall straight back at dispatch).
    """
    from repro.exec import auto as auto_mod
    devices = _mesh_devices()
    if devices <= 1 or not _sharded_available():
        return P.rebuild(plan, list(plan.steps))
    shard_rows = auto_mod.SHARD_ROWS

    schemas = _schemas(plan)
    new_steps: list[P.PlanStep] = []
    for step in plan.steps:
        if step.logical is None:
            new_steps.append(step)
            continue
        notes: list[str] = []

        def route(op: LogicalOp) -> LogicalOp:
            op = _map_children(op, route)
            if not (isinstance(op, Aggregate)
                    and op.strategy == "auto" and len(op.keys) == 1):
                return op
            tables = sorted(op.child.scan_tables())
            if len(tables) != 1:
                return op
            table = tables[0]
            st = (step.input_stats or {}).get(table)
            n = getattr(st, "n_rows", None)
            if n is None or n < shard_rows:
                return op
            key = op.keys[0]
            sch = schemas.get(table)
            if (sch is None or key not in sch.columns()
                    or sch.columns()[key].dtype.family != "int"):
                return op
            notes.append(
                f"partial_agg: aggregate on {table!r} routed to "
                f"sharded partial aggregation (rows={n} >= "
                f"{shard_rows}, devices={devices})")
            return dataclasses.replace(op, strategy="partial")

        tree = route(step.logical)
        if notes:
            step = dataclasses.replace(
                step, logical=tree,
                provenance=step.provenance + tuple(notes))
        new_steps.append(step)
    return P.rebuild(plan, new_steps)


def _mesh_devices() -> int:
    try:
        import jax
        return len(jax.devices())
    except ImportError:
        return 1


def _sharded_available() -> bool:
    from repro import exec as exec_backends
    try:
        exec_backends.get_backend("sharded")
    except (KeyError, exec_backends.BackendUnavailable):
        return False
    return True


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

PASSES: dict[str, Callable[[P.Plan], P.Plan]] = {
    "filter_pushdown": filter_pushdown,
    "join_reorder": join_reorder,
    "column_pruning": column_pruning,
    "probe_fusion": probe_fusion,
    "partial_agg": partial_agg,
}

# Order matters: pushdown first (creates the Filter(Scan) shapes the
# later passes feed on), reorder over the cleaned chain, pruning once
# the tree's reads are final, fusion next (it consumes the remaining
# Filter-before-Join shapes), and partial_agg last — pure physical
# routing over the finished tree.
DEFAULT_PASSES = ("filter_pushdown", "join_reorder", "column_pruning",
                  "probe_fusion", "partial_agg")


def optimize(plan: P.Plan,
             passes: "Sequence[str] | None" = None) -> P.Plan:
    """Run the rewrite pipeline; returns a new Plan with waves
    recomputed, provenance recorded, and the active pass list stamped
    on every step (engine cache keys fold it — flipping a pass can
    never serve a stale cross-plan cache hit)."""
    from repro.obs import get_recorder

    active = tuple(passes) if passes is not None else DEFAULT_PASSES
    rec = get_recorder()
    out = plan
    for name in active:
        try:
            fn = PASSES[name]
        except KeyError:
            raise ValueError(
                f"unknown optimizer pass {name!r} "
                f"(registered: {sorted(PASSES)})") from None
        if rec.enabled:
            # provenance entries are appended per step — the per-pass
            # delta is exactly the rewrites THIS pass performed (steps
            # the pass materialized count whole).
            prev = {s.node.name: len(s.provenance) for s in out.steps}
            with rec.span("optimizer_pass", name=name) as sp:
                out = fn(out)
                new = [p for s in out.steps
                       for p in s.provenance[prev.get(s.node.name, 0):]]
                sp.set(rewrites=len(new), provenance=new)
        else:
            out = fn(out)
    stamped = tuple(dataclasses.replace(s, opt_passes=active)
                    for s in out.steps)
    return P.rebuild(out, stamped, optimizer_passes=active)
