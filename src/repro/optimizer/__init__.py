"""Cost-based plan optimizer over the logical IR (DESIGN.md §11).

``optimize(plan)`` runs a pipeline of rewrite passes — each a pure
``Plan -> Plan`` function with recorded provenance — over the logical
trees that :func:`repro.core.planner.plan` lowered from inspectable
declarative nodes:

- ``filter_pushdown``: filters move below joins onto the side they
  provably read; filters shared by several steps materialize once as
  an unpublished auxiliary step;
- ``join_reorder``: all-inner left-deep chains execute smallest-
  estimated side first (planner ``TableStats`` cardinalities), with
  the authored row order restored bit-for-bit;
- ``column_pruning``: dead source columns are elided, but only when no
  contract verifier and no downstream step references them
  (Appendix-A soundness via ``contracts.referenced_columns``);
- ``probe_fusion``: a filter feeding a join collapses into the join's
  masked probe (``Backend.masked_hash_join`` /
  ``kernels.hash_join.masked_hash_probe``), so filtered rows never
  materialize — on the Pallas path they never leave VMEM;
- ``partial_agg``: large single-int-key aggregations route to the
  sharded backend's pre-exchange partial aggregation
  (``Aggregate.strategy="partial"``) — physical routing, with the
  strategy rendered in the tree description so cache keys move.

Every pass must preserve published tables bit for bit (``partial_agg``
within the documented float-SUM/MEAN summation-order carve-out); the
proof obligation is the differential suite
(``tests/test_optimizer_differential.py``). Pass membership and
per-step provenance are folded into engine cache keys, so toggling a
pass can never serve a stale cached result.
"""
from repro.optimizer.passes import (DEFAULT_PASSES, PASSES,
                                    column_pruning, filter_pushdown,
                                    join_reorder, optimize,
                                    partial_agg, probe_fusion)

__all__ = ["DEFAULT_PASSES", "PASSES", "optimize", "filter_pushdown",
           "join_reorder", "column_pruning", "probe_fusion",
           "partial_agg"]
