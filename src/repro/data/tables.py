"""Columnar tables and the expression language of the paper's listings.

A :class:`Table` is an immutable set of named columns (numpy-backed, with
validity masks for nullability) — the in-memory stand-in for an Iceberg
table snapshot. The expression API mirrors the paper's nodes::

    df.select([col('col2'),
               lit(0.5).alias('col4'),
               arrow_cast(col('col4'), str_lit('Int64')).alias('col4')])
    df.filter(col('col5').is_not_null() & ((col('a') - col('b')) < 0.5))
    df.join(other, on=['col2'], how='inner')

Logical dtypes follow :mod:`repro.core.schema` so worker-side contract
validation (:func:`repro.core.contracts.validate_table`) checks *physical*
data against declared schemas, including nullability.

The relational operators dispatch through the pluggable execution
backends of :mod:`repro.exec` (DESIGN.md §9): ``reference`` (row-loop
oracle), ``vectorized`` (numpy, default), ``jax`` (segment-sum
aggregation). Semantics are backend-independent — the differential
suite (tests/test_exec_backends.py) holds every backend to the
reference bit for bit — and each op takes a per-call ``backend=``
override on top of the process-wide selection.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

from repro import exec as exec_backends

__all__ = ["Table", "GroupedTable", "resolve_agg_specs", "col", "lit",
           "str_lit", "arrow_cast", "Expr"]

_NP_TO_LOGICAL = {
    "int8": "int8", "int16": "int16", "int32": "int32", "int64": "int64",
    "float16": "float16", "float32": "float32", "float64": "float64",
    "bool": "bool", "object": "str", "str": "str",
    "datetime64[ns]": "datetime", "<M8[ns]": "datetime",
}

_LOGICAL_TO_NP = {
    "int8": np.int8, "int16": np.int16, "int32": np.int32,
    "int64": np.int64, "float16": np.float16, "float32": np.float32,
    "float64": np.float64, "bool": np.bool_, "str": object,
    "datetime": "datetime64[ns]",
    # arrow-style names accepted by arrow_cast (paper Listing 5)
    "Int8": np.int8, "Int16": np.int16, "Int32": np.int32,
    "Int64": np.int64, "Float32": np.float32, "Float64": np.float64,
}

_ARROW_TO_LOGICAL = {
    "Int8": "int8", "Int16": "int16", "Int32": "int32", "Int64": "int64",
    "Float32": "float32", "Float64": "float64",
}


def _canon_str_array(arr: np.ndarray) -> np.ndarray:
    """Canonical representation for string columns: object dtype holding
    plain ``str`` / ``None``. Numpy fixed-width ``U``/``S`` arrays (from
    list literals, ``lit``, ``np.full``) are normalized here so the
    logical dtype is always ``str`` and fingerprints/snapshots do not
    depend on the construction path."""
    if arr.dtype.kind == "S":
        arr = np.char.decode(arr, "utf-8")
    out = np.empty(len(arr), dtype=object)
    out[:] = arr.tolist()       # C-level conversion to plain str
    return out


@dataclasses.dataclass(frozen=True)
class _ColumnData:
    values: np.ndarray
    valid: np.ndarray | None = None  # None = no nulls

    def __post_init__(self):
        if self.values.dtype.kind in ("U", "S"):
            object.__setattr__(self, "values",
                               _canon_str_array(self.values))
        if self.valid is not None and not self.valid.all():
            return
        if self.valid is not None:
            object.__setattr__(self, "valid", None)

    @property
    def has_nulls(self) -> bool:
        return self.valid is not None and bool((~self.valid).any())


class Table:
    """Immutable columnar table."""

    def __init__(self, columns: Mapping[str, Any] | None = None,
                 _data: dict[str, _ColumnData] | None = None):
        if _data is not None:
            self._data = _data
        else:
            self._data = {}
            for name, v in (columns or {}).items():
                if isinstance(v, _ColumnData):
                    self._data[name] = v
                    continue
                arr = np.asarray(v)
                valid = None
                if arr.dtype == object:
                    valid = np.array([x is not None for x in arr])
                    if valid.all():
                        valid = None
                self._data[name] = _ColumnData(arr, valid)
        lens = {len(c.values) for c in self._data.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged columns: lengths {sorted(lens)}")

    # -- introspection -------------------------------------------------
    def column_names(self) -> list[str]:
        return list(self._data)

    def __len__(self) -> int:
        if not self._data:
            return 0
        return len(next(iter(self._data.values())).values)

    @property
    def num_rows(self) -> int:
        return len(self)

    def column(self, name: str) -> np.ndarray:
        return self._data[name].values

    def validity(self, name: str) -> np.ndarray:
        c = self._data[name]
        return (c.valid if c.valid is not None
                else np.ones(len(c.values), dtype=bool))

    def logical_dtype(self, name: str) -> str:
        # numpy U/S string dtypes never reach this point: _ColumnData
        # canonicalizes them to object arrays at construction, and
        # object maps to logical `str` below.
        arr = self._data[name].values
        key = str(arr.dtype)
        if key in _NP_TO_LOGICAL:
            return _NP_TO_LOGICAL[key]
        if np.issubdtype(arr.dtype, np.datetime64):
            return "datetime"
        raise TypeError(f"column {name!r}: unmapped dtype {arr.dtype}")

    def has_nulls(self, name: str) -> bool:
        return self._data[name].has_nulls

    def to_pydict(self) -> dict[str, list]:
        out = {}
        for name, c in self._data.items():
            vals = c.values.tolist()
            if c.valid is not None:
                vals = [v if ok else None
                        for v, ok in zip(vals, c.valid)]
            out[name] = vals
        return out

    def fingerprint(self) -> str:
        import hashlib
        h = hashlib.sha256()
        for name in sorted(self._data):
            c = self._data[name]
            h.update(name.encode())
            if c.values.dtype == object:
                # canonical repr: plain str / None (np.str_ etc. vary
                # by construction path but compare equal)
                canon = [None if v is None else str(v)
                         for v in c.values.tolist()]
                h.update(str(canon).encode())
            else:
                h.update(np.ascontiguousarray(c.values).tobytes())
            if c.valid is not None:
                h.update(c.valid.tobytes())
        return h.hexdigest()[:24]

    # -- serialization (object-store snapshots) -------------------------
    def to_blobs(self, store) -> str:
        """Persist as a content-addressed snapshot; returns manifest key."""
        manifest = {"kind": "table", "columns": {}}
        for name, c in self._data.items():
            vals = c.values
            if vals.dtype == object:
                enc = np.array([("" if v is None else str(v))
                                for v in vals])
                key = store.put_array(enc.astype("U"))
                kind = "str"
            elif np.issubdtype(vals.dtype, np.datetime64):
                key = store.put_array(vals.astype("int64"))
                kind = "datetime"
            else:
                key = store.put_array(vals)
                kind = "plain"
            vkey = (store.put_array(c.valid)
                    if c.valid is not None else None)
            # dtype recorded so schema inference over a snapshot (the
            # SQL front door's catalog discovery) reads the manifest
            # only, never the column blobs; "str"/"datetime" kinds pin
            # the logical dtype already.
            manifest["columns"][name] = {"values": key, "valid": vkey,
                                         "kind": kind,
                                         "dtype": str(vals.dtype)}
        return store.put_json(manifest)

    @classmethod
    def from_blobs(cls, store, key: str) -> "Table":
        manifest = store.get_json(key)
        data: dict[str, _ColumnData] = {}
        for name, m in manifest["columns"].items():
            vals = store.get_array(m["values"])
            valid = (store.get_array(m["valid"])
                     if m["valid"] is not None else None)
            if m["kind"] == "str":
                vals = _canon_str_array(vals)
                if valid is not None:   # true roundtrip: restore None
                    vals[~valid.astype(bool)] = None
            elif m["kind"] == "datetime":
                vals = vals.astype("datetime64[ns]")
            data[name] = _ColumnData(vals, valid)
        return cls(_data=data)

    # -- backend bridge (repro.exec column dicts) ------------------------
    def _to_cols(self) -> dict[str, tuple[np.ndarray, np.ndarray | None]]:
        return {n: (c.values, c.valid) for n, c in self._data.items()}

    @classmethod
    def _from_cols(cls, cols: Mapping[str, tuple]) -> "Table":
        return cls(_data={n: _ColumnData(v, valid)
                          for n, (v, valid) in cols.items()})

    # -- relational ops (paper's node bodies) ----------------------------
    # Expression evaluation stays here; the physical operators dispatch
    # through repro.exec (DESIGN.md §9). `backend=` overrides the
    # process-wide selection for one call.

    def select(self, exprs: Sequence["Expr"]) -> "Table":
        data: dict[str, _ColumnData] = {}
        for e in exprs:
            name = e.output_name()
            vals, valid = e.evaluate(self)
            data[name] = _ColumnData(vals, valid)
        return Table(_data=data)

    def filter(self, pred: "Expr", *,
               backend: "str | None" = None) -> "Table":
        mask, valid = pred.evaluate(self)
        mask = np.asarray(mask, dtype=bool)
        if valid is not None:
            mask = mask & valid  # SQL semantics: NULL predicate = drop row
        be = exec_backends.resolve(backend)
        return Table._from_cols(be.filter_select(self._to_cols(), mask))

    def join(self, other: "Table", on: Sequence[str],
             how: str = "inner", *,
             backend: "str | None" = None) -> "Table":
        """Hash join. ``inner`` drops NULL-keyed rows from both sides
        (NULL = NULL is not TRUE); ``left`` keeps every left row —
        unmatched rows carry NULL right columns with correct validity
        masks."""
        if how not in ("inner", "left"):
            raise NotImplementedError(
                f"join: how={how!r} not supported (inner, left)")
        be = exec_backends.resolve(backend)
        return Table._from_cols(
            be.hash_join(self._to_cols(), other._to_cols(),
                         tuple(on), how))

    def masked_join(self, other: "Table", on: Sequence[str],
                    how: str = "inner", *,
                    left_pred: "Expr | None" = None,
                    right_pred: "Expr | None" = None,
                    backend: "str | None" = None) -> "Table":
        """Filter-fused hash join: semantically identical to
        ``self.filter(left_pred).join(other.filter(right_pred), ...)``
        but the masks travel into the probe so backends can skip the
        intermediate materialization (the optimizer's probe-fusion
        rewrite targets this entry point)."""
        if how not in ("inner", "left"):
            raise NotImplementedError(
                f"masked_join: how={how!r} not supported (inner, left)")

        def _mask(t: "Table", pred: "Expr | None"):
            if pred is None:
                return None
            mask, valid = pred.evaluate(t)
            mask = np.asarray(mask, dtype=bool)
            if valid is not None:
                mask = mask & valid  # SQL: NULL predicate = drop row
            return mask

        be = exec_backends.resolve(backend)
        return Table._from_cols(
            be.masked_hash_join(self._to_cols(), other._to_cols(),
                                tuple(on), how,
                                left_mask=_mask(self, left_pred),
                                right_mask=_mask(other, right_pred)))

    def group_by(self, keys: Sequence[str]) -> "GroupedTable":
        """Declarative multi-function GROUP BY::

            t.group_by(["k"]).agg(("sum", "v"), ("count", "v", "n"))

        Aggregate fns: ``sum``/``count``/``min``/``max``/``mean``. SQL
        NULL semantics throughout (see ``repro.exec.base``): aggregates
        skip NULL values (an all-NULL group is NULL, except COUNT,
        which counts 0 and is never NULL), and all NULL keys form ONE
        group. In a declarative pipeline the same call lowers to the
        ``Aggregate`` logical op instead of executing eagerly."""
        return GroupedTable(self, tuple(keys))

    def group_by_sum(self, keys: Sequence[str], value: str,
                     out: str | None = None, *,
                     backend: "str | None" = None) -> "Table":
        """GROUP BY keys, SUM(value) — the paper's Listing 1 aggregate,
        now a thin wrapper over :meth:`group_by`'s multi-function path
        (the regression suite pins its fingerprints byte-identical to
        the pre-refactor implementation).

        SQL aggregate semantics over nullable columns: NULL values are
        skipped by SUM (a group whose values are all NULL sums to NULL),
        and NULL keys form their own single group — SQL ``GROUP BY``
        treats all NULLs as one group, unlike join equality.

        The output column defaults to ``{value}_sum`` (deterministically
        de-collided against the key names); an explicit ``out`` that
        names a group key raises instead of silently overwriting it.
        """
        spec = ("sum", value) if out is None else ("sum", value, out)
        return GroupedTable(self, tuple(keys)).agg(spec, backend=backend)

    def concat(self, other: "Table", *,
               backend: "str | None" = None) -> "Table":
        be = exec_backends.resolve(backend)
        return Table._from_cols(
            be.concat(self._to_cols(), other._to_cols()))


# ---------------------------------------------------------------------------
# GROUP BY
# ---------------------------------------------------------------------------

def resolve_agg_specs(keys: Sequence[str],
                      specs: Sequence[tuple]) -> tuple[tuple[str, str, str], ...]:
    """Normalize user-facing agg specs — ``(fn, value)`` or
    ``(fn, value, out)`` — into the backend's ``(fn, value, out)``
    triples. Default output names are ``{value}_{fn}``, deterministically
    de-collided (``{value}_{fn}_{i}``) against the group keys and every
    name already taken by an earlier spec — the exact scheme
    ``group_by_sum`` always used, so its pinned names are unchanged. An
    explicit ``out`` that names a group key raises instead of silently
    overwriting it. Shared by the eager Table path and the declarative
    DAG lowering, so both produce identical plans."""
    if not specs:
        raise ValueError("agg: at least one (fn, value[, out]) spec "
                         "is required")
    used = set(keys)
    resolved: list[tuple[str, str, str]] = []
    for spec in specs:
        if len(spec) == 2:
            fn, value = spec
            out = None
        elif len(spec) == 3:
            fn, value, out = spec
        else:
            raise ValueError(
                f"agg: expected (fn, value) or (fn, value, out), "
                f"got {spec!r}")
        if out is None:
            out = f"{value}_{fn}"
            i = 1
            while out in used:
                out = f"{value}_{fn}_{i}"
                i += 1
        elif out in keys:
            raise ValueError(
                f"agg: out={out!r} collides with a group key; "
                f"pick a distinct output column name")
        elif out in used:
            raise ValueError(
                f"agg: out={out!r} is produced by more than one spec")
        used.add(out)
        resolved.append((fn, value, out))
    return tuple(resolved)


class GroupedTable:
    """The result of :meth:`Table.group_by` — holds the keys and waits
    for :meth:`agg` to name the aggregates."""

    def __init__(self, table: Table, keys: tuple[str, ...]):
        self._table = table
        self._keys = keys

    def agg(self, *specs: tuple, backend: "str | None" = None) -> Table:
        """Execute the aggregation: one output row per distinct key
        tuple in first-appearance order, key columns first, then one
        column per spec."""
        resolved = resolve_agg_specs(self._keys, specs)
        be = exec_backends.resolve(backend)
        return Table._from_cols(
            be.group_by_agg(self._table._to_cols(), self._keys,
                            resolved))


# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------

class Expr:
    def __init__(self, fn: Callable[[Table], tuple[np.ndarray, np.ndarray | None]],
                 name: str, desc: str | None = None, *,
                 _structural: bool = False,
                 refs: "frozenset[str] | None" = None):
        self._fn = fn
        self._name = name
        # structural description: unlike the output name it survives
        # alias(), so two expressions computing different values never
        # describe identically (content-addressed cache keys rely on it).
        self._desc = desc if desc is not None else name
        # set only by the library constructors (col/lit/operators/
        # arrow_cast): marks _desc as a faithful description of the
        # computation. Hand-rolled Expr(fn, name) stays False, which
        # makes any declarative node using it uncacheable (dag.py).
        self._structural = _structural
        # input columns this expression reads, or None when unknown
        # (hand-rolled Expr(fn, name) may read anything). The optimizer
        # refuses to push/elide around any expression with None refs.
        self._refs = refs

    def references(self) -> "frozenset[str] | None":
        """Set of input-column names this expression reads; ``None``
        means "unknown — could read anything" (opaque callables)."""
        return self._refs

    def evaluate(self, t: Table) -> tuple[np.ndarray, np.ndarray | None]:
        return self._fn(t)

    def output_name(self) -> str:
        return self._name

    def describe(self) -> str:
        if self._desc == self._name:
            return self._desc
        return f"{self._desc} AS {self._name}"

    def alias(self, name: str) -> "Expr":
        return Expr(self._fn, name, self._desc,
                    _structural=self._structural, refs=self._refs)

    def is_not_null(self) -> "Expr":
        def fn(t: Table):
            _, valid = self._fn(t)
            n = len(t)
            out = (valid.copy() if valid is not None
                   else np.ones(n, dtype=bool))
            return out, None
        return Expr(fn, f"{self._name}_is_not_null",
                    f"is_not_null({self._desc})",
                    _structural=self._structural, refs=self._refs)

    def _binop(self, other: Any, op, sym: str) -> "Expr":
        other_e = other if isinstance(other, Expr) else lit(other)

        def fn(t: Table):
            lv, lva = self._fn(t)
            rv, rva = other_e._fn(t)
            if lva is None and rva is None:
                valid = None
            else:
                la = lva if lva is not None else np.ones(len(t), bool)
                ra = rva if rva is not None else np.ones(len(t), bool)
                valid = la & ra
            if valid is not None and (lv.dtype == object
                                      or rv.dtype == object):
                # NULL lanes of object columns hold None payloads; numpy
                # object-dtype ufuncs evaluate EVERY lane, so e.g.
                # None - 1 raises TypeError even though validity masks
                # the lane out. Evaluate only the valid lanes; invalid
                # lanes keep the canonical object fill (None), so the
                # result fingerprints identically however it was built.
                vals = np.full(len(t), None, dtype=object)
                if valid.any():
                    vals[valid] = op(lv[valid], rv[valid])
            else:
                vals = op(lv, rv)
            return vals, valid
        refs = (self._refs | other_e._refs
                if self._refs is not None and other_e._refs is not None
                else None)
        return Expr(fn, f"({self._name}{sym}{other_e._name})",
                    f"({self._desc}{sym}{other_e._desc})",
                    _structural=self._structural and other_e._structural,
                    refs=refs)

    def _unop(self, op, sym: str) -> "Expr":
        def fn(t: Table):
            vals, valid = self._fn(t)
            return op(vals), valid
        return Expr(fn, f"({sym}{self._name})", f"({sym}{self._desc})",
                    _structural=self._structural, refs=self._refs)

    def __invert__(self): return self._unop(np.logical_not, "~")
    def __neg__(self): return self._unop(np.negative, "-")

    def __add__(self, o): return self._binop(o, np.add, "+")
    def __sub__(self, o): return self._binop(o, np.subtract, "-")
    def __mul__(self, o): return self._binop(o, np.multiply, "*")
    def __truediv__(self, o): return self._binop(o, np.true_divide, "/")
    def __lt__(self, o): return self._binop(o, np.less, "<")
    def __le__(self, o): return self._binop(o, np.less_equal, "<=")
    def __gt__(self, o): return self._binop(o, np.greater, ">")
    def __ge__(self, o): return self._binop(o, np.greater_equal, ">=")
    def __eq__(self, o): return self._binop(o, np.equal, "==")  # type: ignore
    def __ne__(self, o): return self._binop(o, np.not_equal, "!=")  # type: ignore
    def __and__(self, o): return self._binop(o, np.logical_and, "&")
    def __or__(self, o): return self._binop(o, np.logical_or, "|")
    __hash__ = None  # type: ignore


def col(name: str) -> Expr:
    def fn(t: Table):
        c = t._data[name]
        return c.values, c.valid
    return Expr(fn, name, _structural=True, refs=frozenset({name}))


def lit(value: Any) -> Expr:
    def fn(t: Table):
        n = len(t)
        if value is None:
            return (np.zeros(n, dtype=object),
                    np.zeros(n, dtype=bool))
        # canonical string representation: object dtype, never
        # fixed-width <U*> (which logical_dtype could not map)
        dtype = object if isinstance(value, (str, bytes)) else None
        arr = np.full(n, value, dtype=dtype)
        return arr, None
    return Expr(fn, repr(value), _structural=True, refs=frozenset())


def str_lit(value: str) -> str:
    """Paper Listing 5: the cast-target literal of ``arrow_cast``."""
    return value


def arrow_cast(expr: Expr, target: str) -> Expr:
    """Explicit cast (paper Listing 5) — required to legally narrow."""
    np_t = _LOGICAL_TO_NP.get(target)
    if np_t is None:
        raise TypeError(f"arrow_cast: unknown target type {target!r}")

    def fn(t: Table):
        vals, valid = expr.evaluate(t)
        return vals.astype(np_t), valid
    e = Expr(fn, expr.output_name(), f"cast({expr._desc}, {target})",
             _structural=expr._structural, refs=expr._refs)
    e.cast_target = _ARROW_TO_LOGICAL.get(target, target)  # type: ignore
    return e
