"""Byte-level tokenizer (vocab 256 + specials), built in-repo.

Deterministic, versionable: the tokenizer spec itself is committed to the
catalog so runs pin the exact vocabulary (the paper's reproducibility
story applies to *all* artifacts, not just tables).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ByteTokenizer:
    vocab_size: int = 259
    pad_id: int = 256
    bos_id: int = 257
    eos_id: int = 258

    def encode(self, text: str, *, add_bos: bool = True,
               add_eos: bool = True) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [self.bos_id] + ids
        if add_eos:
            ids = ids + [self.eos_id]
        return np.array(ids, dtype=np.int32)

    def decode(self, ids) -> str:
        bs = bytes(int(i) for i in ids if int(i) < 256)
        return bs.decode("utf-8", errors="replace")

    def spec(self) -> dict:
        return dataclasses.asdict(self)
