"""Synthetic corpora for training examples and tests.

Generates a deterministic, seeded token stream with learnable structure
(a Markov chain over the vocab + copy motifs) so a ~100M model's loss
visibly decreases within a few hundred steps.
"""
from __future__ import annotations

import numpy as np


def markov_corpus(num_tokens: int, vocab_size: int, *, seed: int = 0,
                  order_bias: float = 6.0) -> np.ndarray:
    """Token stream from a sparse random Markov chain (low entropy)."""
    rng = np.random.default_rng(seed)
    V = vocab_size
    k = min(8, V)
    next_tokens = rng.integers(0, V, size=(V, k))
    logits = rng.normal(size=(V, k)) * order_bias
    probs = np.exp(logits - logits.max(axis=1, keepdims=True))
    probs /= probs.sum(axis=1, keepdims=True)
    out = np.empty(num_tokens, dtype=np.int32)
    tok = int(rng.integers(0, V))
    for i in range(num_tokens):
        out[i] = tok
        j = rng.choice(k, p=probs[tok])
        tok = int(next_tokens[tok, j])
    return out


def copy_task_batch(rng: np.random.Generator, batch: int, seq_len: int,
                    vocab_size: int) -> np.ndarray:
    """[prefix | SEP | prefix] sequences — quick sanity-check task."""
    half = (seq_len - 1) // 2
    prefix = rng.integers(2, vocab_size, size=(batch, half), dtype=np.int32)
    sep = np.ones((batch, 1), dtype=np.int32)
    rest = seq_len - (2 * half + 1)
    pad = np.zeros((batch, rest), dtype=np.int32)
    return np.concatenate([prefix, sep, prefix, pad], axis=1)
