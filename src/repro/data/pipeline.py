"""Deterministic, restartable input pipeline with versioned state.

The pipeline's *cursor* (shard assignment, epoch, step, RNG key) is a
first-class artifact: the training loop commits it in the same
transactional run as params/optimizer snapshots, so a restart resumes
the exact token stream — the paper's replayable-pipelines property
applied to training data (DESIGN.md §2).

Straggler mitigation: shards are leased from a work queue with deadlines;
a shard whose lease expires is reassigned to the next idle reader
(simulated single-process here, exercised in tests).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineState:
    """Everything needed to resume the stream bitwise-identically."""

    shard_order_seed: int
    epoch: int
    step: int

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "PipelineState":
        return cls(**d)


class TokenDataset:
    """A token array split into shards of `shard_tokens` tokens."""

    def __init__(self, tokens: np.ndarray, shard_tokens: int):
        n = (len(tokens) // shard_tokens) * shard_tokens
        self.shards = tokens[:n].reshape(-1, shard_tokens)

    @property
    def num_shards(self) -> int:
        return len(self.shards)


class DataPipeline:
    """Global-batch iterator over a sharded token dataset."""

    def __init__(self, dataset: TokenDataset, *, batch: int, seq_len: int,
                 state: PipelineState | None = None, seed: int = 0):
        self.ds = dataset
        self.batch = batch
        self.seq_len = seq_len
        self.state = state or PipelineState(shard_order_seed=seed,
                                            epoch=0, step=0)
        self._tokens_per_batch = batch * (seq_len + 1)

    def _epoch_order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.state.shard_order_seed, epoch))
        return rng.permutation(self.ds.num_shards)

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (inputs (B,S), targets (B,S)) and advances the cursor."""
        st = self.state
        flat_needed = self._tokens_per_batch
        shard_tokens = self.ds.shards.shape[1]
        shards_per_batch = -(-flat_needed // shard_tokens)
        order = self._epoch_order(st.epoch)
        start = st.step * shards_per_batch
        if start + shards_per_batch > len(order):
            st = PipelineState(st.shard_order_seed, st.epoch + 1, 0)
            order = self._epoch_order(st.epoch)
            start = 0
        idx = order[start:start + shards_per_batch]
        flat = self.ds.shards[idx].reshape(-1)[:flat_needed]
        arr = flat.reshape(self.batch, self.seq_len + 1)
        self.state = PipelineState(st.shard_order_seed, st.epoch,
                                   st.step + 1)
        return arr[:, :-1], arr[:, 1:]


# ---------------------------------------------------------------------------
# Straggler-tolerant shard leasing (work-stealing queue)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Lease:
    shard: int
    reader: str
    deadline: float
    done: bool = False


class ShardLeaseQueue:
    """Deadline-based shard leasing: slow readers lose their lease and the
    shard is reassigned — no shard is lost, no shard is published twice
    (publication goes through the transactional run)."""

    def __init__(self, num_shards: int, *, lease_seconds: float = 30.0,
                 clock=time.monotonic):
        self.pending: list[int] = list(range(num_shards))
        self.leases: dict[int, Lease] = {}
        self.completed: set[int] = set()
        self.lease_seconds = lease_seconds
        self.clock = clock

    def acquire(self, reader: str) -> int | None:
        now = self.clock()
        # reclaim expired leases (straggler mitigation)
        for shard, lease in list(self.leases.items()):
            if not lease.done and lease.deadline < now:
                del self.leases[shard]
                self.pending.append(shard)
        if not self.pending:
            return None
        shard = self.pending.pop(0)
        self.leases[shard] = Lease(shard, reader,
                                   now + self.lease_seconds)
        return shard

    def complete(self, reader: str, shard: int) -> bool:
        lease = self.leases.get(shard)
        if lease is None or lease.reader != reader:
            return False  # lease was reassigned; drop duplicate work
        if shard in self.completed:
            return False
        lease.done = True
        self.completed.add(shard)
        return True

    @property
    def finished(self) -> bool:
        return len(self.completed) == \
            len(self.completed | set(self.pending)) and not self.pending \
            and all(l.done for l in self.leases.values())
