"""Loop-aware HLO analysis: FLOPs, collective bytes, roofline terms.

Why not ``compiled.cost_analysis()`` alone? XLA's cost analysis counts a
``while`` body **once**, not × trip-count (verified experimentally — see
EXPERIMENTS.md §Roofline notes). Our models scan over layers and over
attention tiles, so raw cost_analysis under-reports FLOPs by ~L× and
misses every collective inside the layer loop. This module parses the
optimized HLO text instead:

- builds the computation call graph (while bodies, fusions, calls);
- recovers each while loop's **trip count** from the comparison constant
  in its condition computation (validated against known trip counts in
  ``tests/test_roofline.py``);
- multiplies per-computation costs by the product of enclosing trip
  counts;
- FLOPs: every ``dot`` contributes 2 · |out| · |contracted dims| (and
  ``convolution`` 2 · |out| · |kernel|); elementwise FLOPs are ignored
  (sub-1% for these models);
- collective bytes: operand payload of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (+ ``-start``
  async variants), loop-multiplied.

Roofline terms (seconds, per step, whole mesh):
    compute    = FLOPs_total   / (chips · PEAK_FLOPS)
    memory     = HBM bytes     / (chips · HBM_BW)   [analytic model]
    collective = coll bytes    / (chips · ICI_BW)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Mapping

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Tensors smaller than this inside loop bodies are assumed VMEM-resident
# (v5e VMEM = 128 MiB; double-buffered 32 MiB loop carries / tiles never
# round-trip HBM between scan iterations).
_VMEM_RESIDENT_BYTES = 32 * 2**20

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")


def _parse_type(t: str) -> list[tuple[str, tuple[int, ...]]]:
    """'f32[2,3]{1,0}' or '(f32[2], s32[])' -> [(dtype, shape), ...]."""
    out = []
    for m in _SHAPE_RE.finditer(t):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",") if x) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(t: str) -> int:
    total = 0
    for dt, shape in _parse_type(t):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list[_Op]
    text: str


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_computations(hlo: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    buf: list[str] = []
    for line in hlo.splitlines():
        # tuple types embed /*index=N*/ comments whose '=' breaks the
        # lazy type matcher — strip all comments first.
        line = _COMMENT_RE.sub("", line)
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = _Computation(m.group(1), [], "")
                buf = [line]
            continue
        buf.append(line)
        if line.strip() == "}":
            cur.text = "\n".join(buf)
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(_Op(m.group(1), m.group(2), m.group(3), line))
    return comps


_KNOWN_TRIPS_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _trip_count(cond: _Computation) -> int:
    """Max integer constant in the condition computation ≈ loop bound."""
    consts = [int(x) for x in
              re.findall(r"constant\((\d+)\)", cond.text)]
    return max(consts) if consts else 1


def _op_trip_count(op: _Op, comps: dict[str, _Computation]) -> int:
    """Trip count of a `while` op: exact backend_config annotation when
    present (XLA loop analysis), else the condition-constant heuristic."""
    m = _KNOWN_TRIPS_RE.search(op.line)
    if m:
        return int(m.group(1))
    condm = re.search(r"condition=%?([\w\.\-]+)", op.line)
    if condm and condm.group(1) in comps:
        return _trip_count(comps[condm.group(1)])
    return 1


def _callees(op: _Op) -> list[tuple[str, str]]:
    """[(kind, computation name)] referenced by this op."""
    out = []
    for attr in ("condition", "body", "calls", "to_apply",
                 "true_computation", "false_computation"):
        m = re.search(rf"{attr}=%?([\w\.\-]+)", op.line)
        if m:
            out.append((attr, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.line)
    if m:
        for name in m.group(1).split(","):
            out.append(("branch", name.strip().lstrip("%")))
    return out


@dataclasses.dataclass
class HLOCost:
    flops: float
    collective_bytes: float
    collective_ops: dict[str, float]
    dot_count: int
    while_trips: dict[str, int]
    unparsed_dots: int = 0
    hbm_bytes: float = 0.0


def analyze_hlo(hlo: str) -> HLOCost:
    comps = _parse_computations(hlo)
    # entry = the computation whose name contains "main" or the last ENTRY
    entry = None
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    if m:
        entry = m.group(1)
    if entry not in comps:  # fallback: largest computation
        entry = max(comps, key=lambda c: len(comps[c].ops))

    # propagate multipliers through the call graph
    mult: dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        cname = order.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            for kind, callee in _callees(op):
                if callee not in comps:
                    continue
                factor = 1.0
                if kind == "body":
                    factor = float(max(_op_trip_count(op, comps), 1))
                child_mult = mult[cname] * factor
                if callee in mult:
                    mult[callee] = max(mult[callee], child_mult)
                else:
                    mult[callee] = child_mult
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    # fusion bodies: their internal ops are not HBM traffic (the fusion
    # op's own output/operands are) — mark computations referenced by a
    # `fusion` op's `calls=`.
    fusion_bodies: set[str] = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                for kind, callee in _callees(op):
                    if kind == "calls":
                        fusion_bodies.add(callee)

    # HBM-traffic proxy (documented in EXPERIMENTS.md §Roofline notes):
    # every materialized tensor is written once and read ~once, so
    # traffic ≈ 2 · Σ output-bytes of top-level ops (loop-multiplied),
    # skipping metadata-only opcodes. Fusion internals are skipped.
    # In-place updates (dynamic-update-slice, incl. as a fusion root)
    # only touch the update slice — counting the full buffer would
    # overcount a KV-cache append or scan accumulation by trip-count ×
    # buffer/slice. `while`/`call`/`conditional` are skipped: their
    # bodies are traversed with the loop multiplier already.
    _NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "while", "call", "conditional"}

    def _dus_update_bytes(comp: _Computation, op: _Op) -> float | None:
        """If op is (a fusion rooted in) dynamic-update-slice, bytes of
        the update operand; else None."""
        if op.opcode == "dynamic-update-slice":
            target = (comp, op)
        elif op.opcode == "fusion":
            body_name = next((c for k, c in _callees(op) if k == "calls"),
                             None)
            body = comps.get(body_name)
            if body is None:
                return None
            root = next((o for o in body.ops
                         if "ROOT" in o.line.split("=")[0]
                         or o is body.ops[-1]), None)
            if root is None or root.opcode != "dynamic-update-slice":
                return None
            target = (body, root)
        else:
            return None
        bcomp, bop = target
        btypes = {o.name: o.type_str for o in bcomp.ops}
        names = re.findall(r"%([\w\.\-]+)",
                           bop.line.split("(", 1)[1])
        if len(names) >= 2 and names[1] in btypes:
            return float(_nbytes(btypes[names[1]]))
        return None

    # name -> type map (per computation, for operand shape lookup)
    flops = 0.0
    coll_bytes = 0.0
    coll_ops: dict[str, float] = {}
    dot_count = 0
    unparsed = 0
    trips_out: dict[str, int] = {}
    hbm = 0.0

    for cname, comp in comps.items():
        m_c = mult.get(cname, 0.0)
        if m_c == 0.0:
            continue
        types = {op.name: op.type_str for op in comp.ops}
        is_body = cname in fusion_bodies
        # parameters: "%p = f32[..] parameter(0)" are ops too (covered)
        for op in comp.ops:
            if not is_body:
                if op.opcode == "parameter" and cname == entry:
                    hbm += _nbytes(op.type_str)  # weights read once/step
                elif op.opcode not in _NO_TRAFFIC:
                    dus = _dus_update_bytes(comp, op)
                    if dus is not None:
                        # in-place append: slice traffic per trip, but the
                        # buffer is materialized at least once
                        hbm += max(2.0 * dus * m_c,
                                   float(_nbytes(op.type_str)))
                    else:
                        b = _nbytes(op.type_str)
                        # TPU adaptation: per-iteration tensors below the
                        # VMEM-residency threshold never hit HBM (loop
                        # carries / double-buffered tiles stay on-chip)
                        if not (m_c > 1.0 and b < _VMEM_RESIDENT_BYTES):
                            hbm += 2.0 * b * m_c
            if op.opcode == "dot":
                out_t = _parse_type(op.type_str)
                if not out_t:
                    unparsed += 1
                    continue
                _, out_shape = out_t[0]
                out_elems = 1
                for d in out_shape:
                    out_elems *= d
                mdim = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                 op.line)
                ops_m = re.findall(r"%([\w\.\-]+)", op.line.split("(", 1)[1])
                contracted = 1
                if mdim and ops_m:
                    lhs_t = types.get(ops_m[0])
                    if lhs_t:
                        parsed = _parse_type(lhs_t)
                        if parsed:
                            _, lhs_shape = parsed[0]
                            for idx in mdim.group(1).split(","):
                                if idx and int(idx) < len(lhs_shape):
                                    contracted *= lhs_shape[int(idx)]
                if contracted == 1:
                    unparsed += 1
                flops += 2.0 * out_elems * contracted * m_c
                dot_count += 1
            elif op.opcode == "convolution":
                out_t = _parse_type(op.type_str)
                if out_t:
                    _, out_shape = out_t[0]
                    out_elems = 1
                    for d in out_shape:
                        out_elems *= d
                    # kernel size from rhs operand
                    ops_m = re.findall(r"%([\w\.\-]+)",
                                       op.line.split("(", 1)[1])
                    kelems = 1
                    if len(ops_m) > 1 and ops_m[1] in types:
                        parsed = _parse_type(types[ops_m[1]])
                        if parsed:
                            _, kshape = parsed[0]
                            for d in kshape[:-1]:
                                kelems *= d
                    flops += 2.0 * out_elems * kelems * m_c
            else:
                base = op.opcode.replace("-start", "")
                if base in _COLLECTIVES:
                    # payload: operand bytes (names after '(')
                    args = op.line.split("(", 1)[1].split(")", 1)[0]
                    b = 0
                    for nm in re.findall(r"%([\w\.\-]+)", args):
                        if nm in types:
                            b += _nbytes(types[nm])
                    if b == 0:  # fallback: output bytes
                        b = _nbytes(op.type_str)
                    coll_bytes += b * m_c
                    coll_ops[base] = coll_ops.get(base, 0.0) + b * m_c
                elif op.opcode == "while":
                    trips_out[op.name] = _op_trip_count(op, comps)

    return HLOCost(flops=flops, collective_bytes=coll_bytes,
                   collective_ops=coll_ops, dot_count=dot_count,
                   while_trips=trips_out, unparsed_dots=unparsed,
                   hbm_bytes=hbm)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    model_flops: float
    hbm_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float
    bytes_per_device: float

    def row(self) -> dict:
        return dataclasses.asdict(self)


def roofline_terms(*, arch: str, shape: str, mesh: str, chips: int,
                   hlo_flops: float, model_flops: float,
                   hbm_bytes: float, collective_bytes: float,
                   bytes_per_device: float = 0.0) -> Roofline:
    compute_s = hlo_flops / (chips * hw.PEAK_FLOPS_BF16)
    memory_s = hbm_bytes / (chips * hw.HBM_BW)
    collective_s = collective_bytes / (chips * hw.ICI_BW_PER_LINK)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=hlo_flops, model_flops=model_flops,
        hbm_bytes=hbm_bytes, collective_bytes=collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        useful_ratio=(model_flops / hlo_flops if hlo_flops else 0.0),
        bytes_per_device=bytes_per_device)
