"""TPU v5e hardware constants for the roofline model (per chip)."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s per link
HBM_BYTES = 16 * 2**30        # capacity per chip
VMEM_BYTES = 128 * 2**20      # ~128MB vector memory (v5e)
MXU_TILE = 128
