"""Vectorized numpy backend — the default execution backend.

Replaces the interpreted row loops of the ``reference`` oracle with
factorize/sort-based kernels while reproducing its output bit-for-bit
(row order, validity masks, NULL fills, float accumulation order):

- **hash_join**: per-key factorization to dense int64 codes (shared
  dictionary across both sides so codes align), stable sort of the
  right side, ``searchsorted`` range lookup per left row, and a
  vectorized ragged-range expansion. Stable sorting preserves right-
  occurrence order within a key, and left rows are expanded in order —
  exactly the reference's (left row, right occurrence) nesting.
- **group_by_agg**: joint key factorization, group ids renumbered to
  first-appearance order, then one ``ufunc.reduceat`` per aggregate
  spec over the same stably sorted valid lanes (``np.add`` for
  SUM/COUNT, ``np.minimum``/``np.maximum`` for MIN/MAX with invalid
  lanes parked at the identity; MEAN finalized as float64 SUM/COUNT).
  Integer sums are bit-identical to the reference (integer addition is
  associative, wraparound included); float sums — and the means
  finalized from them — are deterministic but exact only up to
  summation order: ``reduceat``'s SIMD partial sums regroup additions,
  which can move the last ulp (the one documented carve-out from the
  bit-for-bit contract, see base.py). MIN/MAX/COUNT have no carve-out.

NULL/NaN conventions (see base.py): join keys that are NULL, NaN, or
NaT get code -1 (match nothing); GROUP BY gives all NULL keys one
shared code and every NaN key its own fresh code. Object columns are
factorized through a Python dict, which *inherits* the reference's
identity-or-equality semantics (e.g. the same ``nan`` object is one
key, two distinct ``nan`` objects are two).

Object-dtype *value* columns cannot be summed by numpy ufuncs; the
aggregation falls back to the reference row loop for exactly that
column kind (group structure stays vectorized).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.exec.base import (AggSpec, Backend, Columns, _column_length,
                             fill_value, normalize_agg_specs,
                             payload_validity)

__all__ = ["VectorizedBackend", "dense_span_affordable", "reduce_ident"]


def reduce_ident(dtype: np.dtype, op: str):
    """Identity element for masked MIN/MAX over ``dtype``: invalid
    lanes are parked here so they can never win the reduction."""
    if dtype.kind == "f":
        return dtype.type(np.inf if op == "min" else -np.inf)
    if dtype.kind == "b":
        return np.bool_(op == "min")
    info = np.iinfo(dtype)
    return dtype.type(info.max if op == "min" else info.min)


def dense_span_affordable(span: int, n_rows: int) -> bool:
    """Is a direct-address table over ``span`` key slots worth it for
    ``n_rows`` total rows? The single source of truth for the
    bincount fast path below AND for the ``auto`` policy's
    dense-int-key row (exec/auto.py) — tune it in one place."""
    return span <= 4 * n_rows + 1024


# ---------------------------------------------------------------------------
# key factorization
# ---------------------------------------------------------------------------

def _factorize_object(values: np.ndarray, ok: np.ndarray,
                      codes: np.ndarray, table: dict) -> int:
    """Dict-factorize an object column's valid lanes into ``codes``
    (invalid lanes stay -1). Python dict lookup is identity-or-equality,
    matching the reference's tuple-key dict exactly."""
    get = table.get
    for i, v in enumerate(values):
        if not ok[i]:
            continue
        c = get(v, -1)
        if c < 0:
            c = len(table)
            table[v] = c
        codes[i] = c
    return len(table)


def _unmatchable(values: np.ndarray) -> np.ndarray | None:
    """Lanes whose payload can never compare equal to anything (NaN /
    NaT) — non-object dtypes only."""
    if values.dtype.kind in "fc":
        return np.isnan(values)
    if values.dtype.kind in "mM":
        return np.isnat(values)
    return None


def _join_codes(left: Columns, right: Columns,
                on: Sequence[str]) -> tuple[np.ndarray, np.ndarray]:
    """Dense join codes for both sides (aligned); -1 = can match nothing
    (NULL / None payload / NaN / NaT key component)."""
    n_left = _column_length(left)
    combined: np.ndarray | None = None
    for k in on:
        lv, lval = left[k]
        rv, rval = right[k]
        ok = np.concatenate([payload_validity(lv, lval),
                             payload_validity(rv, rval)])
        if (lv.dtype == object or rv.dtype == object
                or lv.dtype.kind != rv.dtype.kind):
            # object columns, and cross-kind keys (int64 vs float64,
            # int vs uint64): dict-factorize boxed payloads so matching
            # is exact Python equality — np.concatenate would promote
            # mixed kinds to float64 and silently collapse 2**53 with
            # 2**53+1.
            values = np.concatenate([
                lv if lv.dtype == object else lv.astype(object),
                rv if rv.dtype == object else rv.astype(object)])
            codes = np.full(len(values), -1, dtype=np.int64)
            _factorize_object(values, ok, codes, {})
        else:
            values = np.concatenate([lv, rv])
            bad = _unmatchable(values)
            if bad is not None:
                ok = ok & ~bad
            codes = np.full(len(values), -1, dtype=np.int64)
            if ok.any():
                _, inv = np.unique(values[ok], return_inverse=True)
                codes[ok] = inv
        combined = codes if combined is None else _merge_codes(
            combined, codes)
    assert combined is not None, "join requires at least one key"
    return combined[:n_left], combined[n_left:]


def _merge_codes(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Combine two per-column code arrays into joint codes, compacting
    with np.unique at every step so the intermediate product never
    overflows int64. -1 (unmatchable) in either column poisons the row."""
    ok = (a >= 0) & (b >= 0)
    out = np.full(len(a), -1, dtype=np.int64)
    if ok.any():
        merged = a[ok] * (int(b.max()) + 1) + b[ok]
        _, inv = np.unique(merged, return_inverse=True)
        out[ok] = inv
    return out


def _group_codes(cols: Columns, keys: Sequence[str]) -> np.ndarray:
    """Dense GROUP BY codes (all lanes >= 0): NULL key components share
    ONE code per column; NaN/NaT components each get a fresh code (the
    reference's dict-of-boxed-scalars gives every NaN its own group)."""
    n = _column_length(cols)
    if not keys:
        return np.zeros(n, dtype=np.int64)
    combined: np.ndarray | None = None
    for k in keys:
        values, valid = cols[k]
        ok = payload_validity(values, valid)
        codes = np.full(n, -1, dtype=np.int64)
        if values.dtype == object:
            # dict factorization already keeps distinct NaN objects
            # distinct (hash collides, equality fails -> separate keys)
            card = _factorize_object(values, ok, codes, {})
        else:
            bad = _unmatchable(values)
            distinct = ok & bad if bad is not None else np.zeros(n, bool)
            plain = ok & ~distinct
            card = 0
            if plain.any():
                _, inv = np.unique(values[plain], return_inverse=True)
                codes[plain] = inv
                card = int(inv.max()) + 1
            if distinct.any():        # one fresh code per NaN/NaT lane
                m = int(distinct.sum())
                codes[distinct] = card + np.arange(m)
                card += m
        codes[codes < 0] = card       # the single NULL group
        combined = codes if combined is None else _merge_group_codes(
            combined, codes)
    return combined


def _merge_group_codes(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if not len(a):
        return a
    merged = a * (int(b.max()) + 1) + b
    _, inv = np.unique(merged, return_inverse=True)
    return inv.reshape(-1).astype(np.int64, copy=False)


def _group_runs(codes: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """One stable sort of ``codes`` -> (order, bounds, grp_order, rep).

    ``order`` sorts rows into group runs; ``bounds`` marks run starts in
    sorted-row space; ``grp_order`` permutes code-ordered groups into
    first-appearance order (the reference's dict-insertion order) and
    ``rep`` is each group's first row index, in output order."""
    if not len(codes):                  # zero rows -> zero groups
        empty = np.array([], dtype=np.int64)
        return empty, empty, empty, empty
    order = np.argsort(codes, kind="stable")
    cs = codes[order]
    bounds = np.flatnonzero(np.r_[True, cs[1:] != cs[:-1]])
    first_rows = order[bounds]      # stable sort: earliest row per run
    grp_order = np.argsort(first_rows, kind="stable")
    return order, bounds, grp_order, first_rows[grp_order]


def _and_key_validity(cols: Columns, on: Sequence[str],
                      mask: np.ndarray) -> Columns:
    """AND a keep-mask into the *key columns'* validity (shallow copy).

    Masked-out rows then look NULL-keyed to the probe, so inner-join
    emission drops them without a filter pass. Sound only because
    ``_gather_right`` never copies a key column that the left side
    already produced — the poisoned key validity never reaches the
    output (left keys: every emitted inner lane has mask True, so the
    AND is a no-op on survivors)."""
    out = dict(cols)
    keep = np.asarray(mask, dtype=bool)
    for k in on:
        values, valid = out[k]
        valid = keep if valid is None else (valid & keep)
        out[k] = (values, valid)
    return out


# ---------------------------------------------------------------------------
# the backend
# ---------------------------------------------------------------------------

class VectorizedBackend(Backend):
    name = "vectorized"

    # -- join -----------------------------------------------------------
    def hash_join(self, left: Columns, right: Columns,
                  on: Sequence[str], how: str = "inner") -> Columns:
        fast = self._single_key_probe(left, right, on)
        if fast is not None:
            n_left, starts, counts, ridx = fast
        else:
            lcodes, rcodes = _join_codes(left, right, on)
            n_left = len(lcodes)
            rvalid = np.flatnonzero(rcodes >= 0)
            order = np.argsort(rcodes[rvalid], kind="stable")
            rsorted = rcodes[rvalid][order]
            ridx = rvalid[order]        # right rows, sorted by code,
            #                             occurrence order within a code
            starts = np.searchsorted(rsorted, lcodes, side="left")
            ends = np.searchsorted(rsorted, lcodes, side="right")
            counts = np.where(lcodes >= 0, ends - starts, 0)
        return self._emit_join(left, right, how, n_left, starts, counts,
                               ridx)

    def masked_hash_join(self, left: Columns, right: Columns,
                         on: Sequence[str], how: str = "inner", *,
                         left_mask: np.ndarray | None = None,
                         right_mask: np.ndarray | None = None
                         ) -> Columns:
        """Fused filtering: AND the keep-masks into the key columns'
        validity and run the normal probe — a masked row looks
        NULL-keyed, matches nothing, and (for inner joins) is never
        emitted. No intermediate filtered table is materialized.

        The one case that MUST prefilter: ``how='left'`` with a
        ``left_mask`` — a NULL-keyed left row still emits (once, with
        NULL right columns) under left-join semantics, but a
        filtered-out row must not emit at all. Right masks are safe for
        both hows (masked right rows simply stop matching), and
        ``_gather_right`` skips key columns the left side already
        produced, so the poisoned right key validity never surfaces.
        """
        if left_mask is not None and how != "inner":
            left = self.filter_select(left, left_mask)
            left_mask = None
        if left_mask is not None:
            left = _and_key_validity(left, on, left_mask)
        if right_mask is not None:
            right = _and_key_validity(right, on, right_mask)
        return self.hash_join(left, right, on, how)

    def _emit_join(self, left: Columns, right: Columns, how: str,
                   n_left: int, starts: np.ndarray, counts: np.ndarray,
                   ridx: np.ndarray) -> Columns:
        """Ragged-match emission shared by every probe strategy.

        ``ridx`` lists right rows grouped by key (matches for a key are
        contiguous, in right-occurrence order); left row ``i``'s matches
        are ``ridx[starts[i] : starts[i] + counts[i]]``. The grouped
        layout need not be globally key-sorted — the sharded backend
        concatenates per-shard runs — only per-key contiguous.
        """
        unique_match = int(counts.max()) <= 1 if len(counts) else True
        if how == "inner":
            if unique_match:
                # FK-join shape (every left row matches <= 1 right row):
                # the ragged expansion collapses to two gathers.
                li = np.flatnonzero(counts)
                ri = ridx[starts[li]]
            else:
                total = int(counts.sum())
                li = np.repeat(np.arange(n_left), counts)
                run_starts = np.cumsum(counts) - counts
                # pos[j] = starts[row] + (j - run_start[row]): fold both
                # per-row terms into ONE ragged repeat.
                pos = (np.arange(total)
                       + np.repeat(starts - run_starts, counts))
                ri = ridx[pos]
        else:                           # left: unmatched rows emit once
            if unique_match:
                li = np.arange(n_left)
                if len(ridx):
                    safe = np.minimum(starts, len(ridx) - 1)
                    ri = np.where(counts > 0, ridx[safe], -1)
                else:
                    ri = np.full(n_left, -1, dtype=np.int64)
            else:
                counts_out = np.maximum(counts, 1)
                total = int(counts_out.sum())
                li = np.repeat(np.arange(n_left), counts_out)
                run_starts = np.cumsum(counts_out) - counts_out
                has = np.repeat(counts > 0, counts_out)
                pos = (np.arange(total)
                       + np.repeat(np.where(counts > 0, starts, 0)
                                   - run_starts, counts_out))
                if len(ridx):
                    ri = np.where(has, ridx[np.where(has, pos, 0)], -1)
                else:
                    ri = np.full(total, -1, dtype=np.int64)

        out: dict[str, tuple[np.ndarray, np.ndarray | None]] = {}
        for n, (values, valid) in left.items():
            out[n] = (values[li], None if valid is None else valid[li])
        return self._gather_right(out, right, how, li, ri)

    @staticmethod
    def _single_key_probe(left: Columns, right: Columns,
                          on: Sequence[str]):
        """Single non-object key: probe raw values — no factorization
        pass. Returns (n_left, starts, counts, ridx) where ``ridx``
        lists valid right rows stably sorted by key and, per left row,
        its matches are ``ridx[starts : starts + counts]``.

        Two levels: dense *integer* keys probe a direct-address
        bincount table (no binary search at all — the classic
        radix-partition trick, and the common FK-join shape); anything
        else binary-searches the sorted right keys. Either way matching
        is numpy equality, which coincides with the reference's Python
        equality for every non-object dtype (NaN/NaT = unmatchable)."""
        if len(on) != 1:
            return None
        lv, lval = left[on[0]]
        rv, rval = right[on[0]]
        if lv.dtype == object or rv.dtype == object:
            return None
        if lv.dtype.kind != rv.dtype.kind:
            # cross-kind equality (int vs float keys) is defined by
            # Python numeric comparison; leave it to the codes path.
            return None
        lok = payload_validity(lv, lval)
        rok = payload_validity(rv, rval)
        for values, ok in ((lv, lok), (rv, rok)):
            bad = _unmatchable(values)
            if bad is not None:
                ok &= ~bad
        n_left = len(lv)
        rvalid = (np.arange(len(rv)) if rok.all()
                  else np.flatnonzero(rok))
        rvv = rv if len(rvalid) == len(rv) else rv[rvalid]

        if lv.dtype.kind in "iu" and len(rvv) and lok.any():
            lvv = lv if lok.all() else lv[lok]
            mn = min(int(lvv.min()), int(rvv.min()))
            mx = max(int(lvv.max()), int(rvv.max()))
            span = mx - mn + 1
            if (dense_span_affordable(span, n_left + len(rvv))
                    and -2**62 < mn and mx < 2**62):  # int64-safe math
                # direct-address probe: per-key counts/offsets into the
                # key-sorted ridx, then O(1) gathers per left row. The
                # rebased int32 keys also make the stable argsort a
                # 4-pass radix sort.
                key_r = (rvv - mn).astype(np.int32)
                order = np.argsort(key_r, kind="stable")
                ridx = rvalid[order]
                counts_k = np.bincount(key_r, minlength=span)
                offsets = np.concatenate(
                    [np.zeros(1, np.int64), np.cumsum(counts_k)])
                kl = np.clip(lv, mn, mx).astype(np.int64) - mn
                starts = offsets[kl]
                counts = np.where(lok, counts_k[kl], 0)
                return n_left, starts, counts, ridx

        order = np.argsort(rvv, kind="stable")
        ridx = rvalid[order]
        rsorted = rvv[order]
        starts = np.searchsorted(rsorted, lv, side="left")
        ends = np.searchsorted(rsorted, lv, side="right")
        counts = np.where(lok, ends - starts, 0)
        return n_left, starts, counts, ridx

    def _gather_right(self, out: dict, right: Columns, how: str,
                      li: np.ndarray, ri: np.ndarray) -> Columns:
        matched = ri >= 0
        safe = np.where(matched, ri, 0)
        for n, (values, valid) in right.items():
            if n in out:                # join keys: keep left copy
                continue
            if how == "inner":
                out[n] = (values[ri],
                          None if valid is None else valid[ri])
                continue
            if len(values):
                gathered = values[safe]
                gathered[~matched] = fill_value(values.dtype)
                ok = (valid[safe] if valid is not None
                      else np.ones(len(safe), dtype=bool)) & matched
            else:                       # empty right side: all-NULL col
                gathered = np.full(len(safe), fill_value(values.dtype),
                                   dtype=values.dtype)
                ok = np.zeros(len(safe), dtype=bool)
            out[n] = (gathered, ok)
        return out

    # -- aggregation ----------------------------------------------------
    def group_by_agg(self, cols: Columns, keys: Sequence[str],
                     specs: Sequence[AggSpec]) -> Columns:
        specs = normalize_agg_specs(cols, keys, specs)
        order, bounds, grp_order, rep = self._runs_for_keys(cols, keys)
        n_groups = len(rep)
        data: dict[str, tuple[np.ndarray, np.ndarray | None]] = {}
        for kname in keys:
            values, valid = cols[kname]
            ok = payload_validity(values, valid)
            colvals = values[rep]
            mask = ok[rep]
            colvals[~mask] = fill_value(values.dtype)
            data[kname] = (colvals, mask)
        for fn, value, out in specs:
            values, valid = cols[value]
            ok = payload_validity(values, valid)
            data[out] = self._agg_one(fn, values, ok, order, bounds,
                                      grp_order, n_groups)
        return data

    @staticmethod
    def _runs_for_keys(cols: Columns, keys: Sequence[str]):
        # single never-NULL integer-kind key: runs of sorted raw values
        # ARE the groups — skip the whole factorization pass.
        if len(keys) == 1:
            kv, kvalid = cols[keys[0]]
            if (kv.dtype != object and kv.dtype.kind in "iub"
                    and kvalid is None):
                return _group_runs(kv)
        return _group_runs(_group_codes(cols, keys))

    def _agg_one(self, fn: str, values: np.ndarray, ok: np.ndarray,
                 order: np.ndarray, bounds: np.ndarray,
                 grp_order: np.ndarray, n_groups: int
                 ) -> tuple[np.ndarray, np.ndarray | None]:
        """One aggregate column over precomputed group runs (runs are
        shared across every spec in a group_by_agg call)."""
        if fn == "sum":
            return self._aggregate(values, ok, order, bounds, grp_order,
                                   n_groups)
        if fn == "count":
            if n_groups == 0:
                return np.array([], dtype=np.int64), None
            counts = np.add.reduceat(
                ok[order].astype(np.int64), bounds)[grp_order]
            return counts, None         # COUNT is int64 and never NULL
        if fn == "mean":
            return self._agg_mean(values, ok, order, bounds, grp_order,
                                  n_groups)
        return self._agg_minmax(fn, values, ok, order, bounds,
                                grp_order, n_groups)

    def _agg_mean(self, values, ok, order, bounds, grp_order, n_groups):
        # MEAN = SUM/COUNT finalized in float64 (object columns divide
        # in Python) — the shared shippable-partials definition; float
        # inputs inherit the SUM summation-order carve-out.
        if values.dtype == object:
            if n_groups == 0:
                return (np.array([], dtype=object),
                        np.array([], dtype=bool))
            sums, has = self._aggregate_object(values, ok, order, bounds,
                                               grp_order, n_groups)
            counts = np.add.reduceat(
                ok[order].astype(np.int64), bounds)[grp_order]
            res = np.array([None if a is None else a / c
                            for a, c in zip(sums, counts)], dtype=object)
            return res, has
        sums, has = self._aggregate(values, ok, order, bounds, grp_order,
                                    n_groups)
        if n_groups == 0:
            return np.array([], dtype=np.float64), has
        counts = np.add.reduceat(
            ok[order].astype(np.int64), bounds)[grp_order]
        means = sums.astype(np.float64)
        np.divide(means, counts, out=means, where=has)
        means[~has] = fill_value(np.dtype(np.float64))
        return means, has

    def _agg_minmax(self, fn, values, ok, order, bounds, grp_order,
                    n_groups):
        vdt = values.dtype
        if n_groups == 0:
            return (np.array([], dtype=vdt), np.array([], dtype=bool))
        if vdt != object and vdt.kind in "fiub":
            # invalid lanes are parked at the identity so they never
            # win; NaN in a *valid* float lane propagates through
            # minimum/maximum.reduceat exactly like the reference's
            # per-row np.minimum accumulation.
            ident = reduce_ident(vdt, fn)
            masked = np.where(ok, values, ident)[order]
            ufunc = np.minimum if fn == "min" else np.maximum
            red = ufunc.reduceat(masked, bounds)[grp_order]
            counts = np.add.reduceat(
                ok[order].astype(np.int64), bounds)[grp_order]
            has = counts > 0
            red[~has] = fill_value(vdt)
            return red, has
        # object / datetime values: reference-style row-order
        # accumulation per group run.
        n = len(values)
        ends = np.r_[bounds[1:], n]
        acc: list = [None] * n_groups
        for slot, g in enumerate(grp_order):
            a = None
            for row in order[bounds[g]:ends[g]]:
                if not ok[row]:
                    continue
                v = values[row]
                if a is None:
                    a = v
                elif vdt == object:
                    if fn == "min":
                        a = v if v < a else a
                    else:
                        a = v if v > a else a
                else:
                    a = (np.minimum if fn == "min" else np.maximum)(a, v)
            acc[slot] = a
        red = np.array([fill_value(vdt) if a is None else a
                        for a in acc], dtype=vdt)
        has = np.array([a is not None for a in acc], dtype=bool)
        return red, has

    def _aggregate(self, values: np.ndarray, ok: np.ndarray,
                   order: np.ndarray, bounds: np.ndarray,
                   grp_order: np.ndarray, n_groups: int
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Per-group SUM over valid lanes; (sums, group-has-a-value).
        ``order``/``bounds``/``grp_order`` come from :func:`_group_runs`;
        invalid groups carry the canonical fill payload."""
        vdt = values.dtype
        if n_groups == 0:               # reduceat rejects empty bounds
            return (np.array([], dtype=vdt), np.array([], dtype=bool))
        if vdt == object:
            return self._aggregate_object(values, ok, order, bounds,
                                          grp_order, n_groups)
        # invalid lanes contribute the additive identity instead of
        # being dropped: exact for integers, and for floats at most a
        # signed-zero/ulp effect inside the documented float carve-out.
        masked = np.where(ok, values, np.zeros(1, dtype=vdt)[0])[order]
        # row order within a run is preserved (stable sort), so integer
        # sums are bit-identical to the reference; float sums can differ
        # in the last ulp (SIMD partial sums). dtype=vdt keeps the
        # accumulator in the value dtype (reduceat would otherwise
        # promote small ints to platform int, changing wraparound).
        sums = np.add.reduceat(masked, bounds, dtype=vdt)[grp_order]
        counts = np.add.reduceat(
            ok[order].astype(np.int64), bounds)[grp_order]
        has = counts > 0
        sums[~has] = fill_value(vdt)    # canonical fill (zeros)
        return sums, has

    @staticmethod
    def _aggregate_object(values: np.ndarray, ok: np.ndarray,
                          order: np.ndarray, bounds: np.ndarray,
                          grp_order: np.ndarray, n_groups: int
                          ) -> tuple[np.ndarray, np.ndarray]:
        # Python-object arithmetic cannot vectorize: reference-style
        # row-order accumulation, one Python loop per group run.
        n = len(values)
        ends = np.r_[bounds[1:], n]
        acc: list = [None] * n_groups
        for slot, g in enumerate(grp_order):
            a = None
            for row in order[bounds[g]:ends[g]]:
                if ok[row]:
                    v = values[row]
                    a = v if a is None else a + v
            acc[slot] = a
        sums = np.array([fill_value(values.dtype) if a is None else a
                         for a in acc], dtype=values.dtype)
        has = np.array([a is not None for a in acc], dtype=bool)
        return sums, has
