"""Per-table statistics that drive backend auto-selection.

Two producers, one consumer:

- ``planner.plan(pipeline, table_stats=...)`` records source-table
  stats in :class:`~repro.core.planner.PlanStep` metadata at the
  control-plane moment, so a plan describes not just *what* each node
  computes but roughly *how much* — observability for the scheduler
  and for humans reading ``plan.describe()``.
- :class:`~repro.exec.auto.AutoBackend` re-derives the same stats per
  dispatch from the live column dicts (``collect_stats`` is O(sample),
  never O(n·log n)) — the decision point sees exact row counts even
  for intermediate tables whose size the planner could not know.

The statistics are deliberately coarse: row count, key dtype kinds,
an estimated key cardinality from a strided sample, and — for single
integer keys — the value span that decides whether a direct-address
(bincount) probe table is affordable. They feed a *threshold* decision
table (exec/auto.py), so estimate error of 2× is harmless.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.exec.base import Columns, _column_length, payload_validity

__all__ = ["TableStats", "collect_stats"]

_SAMPLE = 4096


@dataclasses.dataclass(frozen=True)
class TableStats:
    """Cheap summary of one table, keyed for a specific operation."""

    n_rows: int
    key_kinds: tuple[str, ...] = ()     # numpy dtype kinds, per key col
    est_key_cardinality: int | None = None
    int_key_span: int | None = None     # max-min+1, single int key only
    # key value bounds (single int key): lets a consumer compute the
    # exact JOINT span of two tables — per-side spans alone
    # underestimate without bound when the sides' key ranges are
    # disjoint.
    int_key_lo: int | None = None
    int_key_hi: int | None = None

    @property
    def single_int_key(self) -> bool:
        return len(self.key_kinds) == 1 and self.key_kinds[0] in "iu"

    def describe(self) -> str:
        bits = [f"rows={self.n_rows}"]
        if self.key_kinds:
            bits.append(f"keys={','.join(self.key_kinds)}")
        if self.est_key_cardinality is not None:
            bits.append(f"card~{self.est_key_cardinality}")
        if self.int_key_span is not None:
            bits.append(f"span={self.int_key_span}")
        return " ".join(bits)


def _estimate_cardinality(values: np.ndarray, ok: np.ndarray) -> int:
    """Distinct-count estimate from a strided sample: exact for small
    tables, a linear scale-up of sample distinctness for large ones
    (saturating — a saturated sample reads as 'all distinct')."""
    n = len(values)
    if n == 0:
        return 0
    if n <= _SAMPLE:
        idx = np.flatnonzero(ok)
    else:
        stride = max(1, n // _SAMPLE)
        idx = np.arange(0, n, stride)
        idx = idx[ok[idx]]
    if len(idx) == 0:
        return 0
    sample = values[idx]
    if values.dtype == object:
        distinct = len({v for v in sample})
    else:
        distinct = len(np.unique(sample))
    if n <= _SAMPLE or distinct < max(1, len(idx) // 2):
        return distinct
    # sample nearly all-distinct: assume cardinality scales with n
    return max(distinct, int(distinct * (n / max(1, len(idx)))))


def collect_stats(cols: Columns, keys: Sequence[str] = (), *,
                  estimate_cardinality: bool = True) -> TableStats:
    """``estimate_cardinality=False`` skips the sampling pass and
    leaves ``est_key_cardinality`` None — the auto policy's decision
    table reads only rows/kinds/span, so its per-dispatch collection
    pays nothing it does not use; plan-time metadata keeps the
    estimate for observability."""
    n = _column_length(cols)
    kinds: list[str] = []
    card: int | None = None
    span: int | None = None
    lo: int | None = None
    hi: int | None = None
    for k in keys:
        values, valid = cols[k]
        kinds.append("O" if values.dtype == object else values.dtype.kind)
    if len(keys) == 1:
        values, valid = cols[keys[0]]
        ok = payload_validity(values, valid)
        if estimate_cardinality:
            card = _estimate_cardinality(values, ok)
        if values.dtype != object and values.dtype.kind in "iu" \
                and ok.any():
            vv = values[ok] if not ok.all() else values
            lo, hi = int(vv.min()), int(vv.max())
            span = hi - lo + 1
    elif keys and estimate_cardinality:
        cards = []
        for k in keys:
            values, valid = cols[k]
            cards.append(_estimate_cardinality(
                values, payload_validity(values, valid)))
        # joint cardinality is at most the product, at most n
        prod = 1
        for c in cards:
            prod = min(prod * max(c, 1), n if n else 1)
        card = prod
    return TableStats(n_rows=n, key_kinds=tuple(kinds),
                      est_key_cardinality=card, int_key_span=span,
                      int_key_lo=lo, int_key_hi=hi)
