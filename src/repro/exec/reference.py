"""The row-loop reference backend — the differential-testing oracle.

This is the table layer's original interpreted implementation (PR 2
semantics), extracted verbatim from ``repro.data.tables`` and extended
with ``how="left"``. It is deliberately naive: Python dicts of boxed
key tuples, per-row loops, first-appearance group ordering via dict
insertion. Its value is *semantic*, not performance — every other
backend must reproduce its output bit-for-bit (values, validity masks,
row order, and the typed fills in invalid lanes), which is what
``tests/test_exec_backends.py`` asserts.

Because keys are compared with Python dict/tuple equality, the oracle
pins down the edge semantics the vectorized backends must reproduce:
``NULL`` (mask or ``None`` payload) matches nothing in joins; NaN keys
match nothing (``NaN != NaN``); GROUP BY collapses all NULL keys into
one group while each NaN key stays its own group.
"""
from __future__ import annotations

from typing import Any, Sequence

import numpy as np

from repro.exec.base import (AggSpec, Backend, Columns, _column_length,
                             fill_value, normalize_agg_specs,
                             payload_validity)

__all__ = ["ReferenceBackend"]

# Sentinel marking a NULL group key in group_by_agg: SQL GROUP BY puts
# all NULL keys in one group (unlike join equality, which matches none).
_NULL = object()


class ReferenceBackend(Backend):
    name = "reference"

    # -- join -----------------------------------------------------------
    def hash_join(self, left: Columns, right: Columns,
                  on: Sequence[str], how: str = "inner") -> Columns:
        # SQL semantics: NULL join keys match nothing (NULL = NULL is
        # not true). Inner: null-keyed rows are dropped from both sides;
        # left: null-keyed/unmatched left rows survive with NULL right
        # columns.
        lok = self._key_validity(left, on)
        rok = self._key_validity(right, on)
        lkeys = list(zip(*(left[k][0] for k in on)))
        rindex: dict[tuple, list[int]] = {}
        rkeys = list(zip(*(right[k][0] for k in on)))
        for i, k in enumerate(rkeys):
            if rok[i]:
                rindex.setdefault(k, []).append(i)
        li, ri = [], []
        for i, k in enumerate(lkeys):
            matches = rindex.get(k, ()) if lok[i] else ()
            if not matches:
                if how == "left":       # unmatched: keep, right = NULL
                    li.append(i)
                    ri.append(-1)
                continue
            for j in matches:
                li.append(i)
                ri.append(j)
        li_arr = np.array(li, dtype=int)
        ri_arr = np.array(ri, dtype=int)
        out: dict[str, tuple[np.ndarray, np.ndarray | None]] = {}
        for n, (values, valid) in left.items():
            out[n] = (values[li_arr] if len(li_arr) else values[:0],
                      None if valid is None else valid[li_arr])
        matched = ri_arr >= 0
        safe = np.where(matched, ri_arr, 0)
        for n, (values, valid) in right.items():
            if n in out:                # join keys: keep left copy
                continue
            if how == "inner":
                out[n] = (values[ri_arr] if len(ri_arr) else values[:0],
                          None if valid is None else valid[ri_arr])
                continue
            if len(values):
                gathered = (values[safe] if len(safe) else values[:0])
                gathered[~matched] = fill_value(values.dtype)
                ok = (valid[safe] if valid is not None
                      else np.ones(len(safe), dtype=bool)) & matched
            else:                       # empty right side: all-NULL col
                gathered = np.full(len(safe), fill_value(values.dtype),
                                   dtype=values.dtype)
                ok = np.zeros(len(safe), dtype=bool)
            out[n] = (gathered, ok)
        return out

    @staticmethod
    def _key_validity(cols: Columns, on: Sequence[str]) -> np.ndarray:
        """Rows whose every join key is non-NULL (validity mask AND no
        ``None`` payload in object columns)."""
        ok = np.ones(_column_length(cols), dtype=bool)
        for k in on:
            values, valid = cols[k]
            ok &= payload_validity(values, valid)
        return ok

    # -- aggregation ----------------------------------------------------
    def group_by_agg(self, cols: Columns, keys: Sequence[str],
                     specs: Sequence[AggSpec]) -> Columns:
        # SQL aggregate semantics over nullable columns: SUM/MIN/MAX/
        # MEAN skip NULL values (an all-NULL group aggregates to NULL),
        # COUNT counts non-NULL values and is never NULL, and NULL keys
        # form their own single group. Two row loops: one assigns group
        # slots in first-appearance (dict-insertion) order, then each
        # spec accumulates in row order — the same order the original
        # single-pass group_by_sum used, so SUM results are bit-for-bit
        # unchanged.
        specs = normalize_agg_specs(cols, keys, specs)
        n = _column_length(cols)
        kcols = [cols[k][0] for k in keys]
        kvalid = [self._validity(cols[k]) for k in keys]
        groups: dict[tuple, int] = {}
        order: list[tuple] = []
        gid = np.empty(n, dtype=np.int64)
        for i in range(n):
            k = tuple(c[i] if kvalid[j][i] and c[i] is not None else _NULL
                      for j, c in enumerate(kcols))
            slot = groups.get(k)
            if slot is None:
                slot = len(order)
                groups[k] = slot
                order.append(k)
            gid[i] = slot
        data: dict[str, tuple[np.ndarray, np.ndarray | None]] = {}
        for j, kname in enumerate(keys):
            dt = kcols[j].dtype
            fill = fill_value(dt)
            colvals = np.array([fill if k[j] is _NULL else k[j]
                                for k in order], dtype=dt)
            mask = np.array([k[j] is not _NULL for k in order], dtype=bool)
            data[kname] = (colvals, mask)
        for fn, value, out in specs:
            data[out] = self._agg_one(fn, cols[value], gid, len(order))
        return data

    @staticmethod
    def _agg_one(fn: str, col: tuple[np.ndarray, "np.ndarray | None"],
                 gid: np.ndarray, n_groups: int
                 ) -> tuple[np.ndarray, np.ndarray | None]:
        vals, valid = col
        ok = payload_validity(vals, valid)
        counts = np.zeros(n_groups, dtype=np.int64)
        acc: list[Any] = [None] * n_groups
        is_object = vals.dtype == object
        for i in range(len(vals)):
            if not ok[i]:
                continue
            g = int(gid[i])
            counts[g] += 1
            v = vals[i]
            a = acc[g]
            if a is None:
                acc[g] = v
            elif fn in ("sum", "mean"):
                acc[g] = a + v
            elif fn == "min":
                # object: Python compare (ties keep the accumulator);
                # numeric: np.minimum, which propagates NaN values.
                acc[g] = (v if v < a else a) if is_object else np.minimum(a, v)
            elif fn == "max":
                acc[g] = (v if v > a else a) if is_object else np.maximum(a, v)
        if fn == "count":
            return counts, None         # COUNT is int64 and never NULL
        if fn == "mean":
            if is_object:
                vdt = np.dtype(object)
                res = [None if a is None else a / c
                       for a, c in zip(acc, counts)]
            else:
                # MEAN is always SUM/COUNT finalized in float64 — the
                # shippable-partials definition every backend shares
                # (and the float summation-order carve-out extends to it).
                vdt = np.dtype(np.float64)
                res = [None if a is None else np.float64(a) / c
                       for a, c in zip(acc, counts)]
            fill = fill_value(vdt)
            return (np.array([fill if a is None else a for a in res],
                             dtype=vdt),
                    np.array([a is not None for a in res], dtype=bool))
        vdt = vals.dtype
        fill = fill_value(vdt)
        return (np.array([fill if a is None else a for a in acc],
                         dtype=vdt),
                np.array([a is not None for a in acc], dtype=bool))

    @staticmethod
    def _validity(col: tuple[np.ndarray, "np.ndarray | None"]) -> np.ndarray:
        values, valid = col
        return (valid if valid is not None
                else np.ones(len(values), dtype=bool))
