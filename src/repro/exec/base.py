"""Execution-backend interface for the columnar table layer (DESIGN.md §9).

A backend implements the four physical operators the relational layer
dispatches (:class:`~repro.data.tables.Table` stays the only public
API): ``hash_join``, ``group_by_sum``, ``filter_select`` and ``concat``.
Backends operate on *column dicts* — ``{name: (values, valid)}`` with
numpy value arrays and optional boolean validity masks — rather than on
:class:`Table` itself, so the package has no import cycle with the
table layer and a backend can be exercised (and differentially tested)
without building tables.

Semantics are fixed by the ``reference`` backend (the extracted
row-loop implementation): every registered backend must agree with it
bit-for-bit — including NULL handling, row order, and the typed fill
payloads it writes into invalid lanes (fills are hashed by
``Table.fingerprint``, so "don't care" lanes still have to match).
One documented carve-out: *float* SUM results are deterministic per
backend but exact only up to summation order across backends (SIMD /
device reductions regroup additions; no engine promises bit-stable
float aggregation across execution strategies). Integer sums have no
carve-out — integer addition is associative even under wraparound.
``tests/test_exec_backends.py`` enforces all of this differentially.

Shared NULL conventions (SQL semantics, established in PR 2):

- join keys: a NULL key matches nothing (``NULL = NULL`` is not TRUE);
  NaN/NaT keys also match nothing (Python/numpy equality agrees);
- GROUP BY keys: all NULL keys form ONE group; NaN keys are pairwise
  distinct (NaN != NaN), so each NaN-keyed row is its own group;
- SUM skips NULL values; a group whose values are all NULL sums to NULL.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

__all__ = ["Columns", "Backend", "fill_value", "payload_validity"]

# {column name: (values, validity-or-None)} — insertion order is column
# order. `valid is None` means "no NULLs" (the Table-layer convention).
Columns = Mapping[str, tuple[np.ndarray, "np.ndarray | None"]]


def fill_value(dtype: np.dtype):
    """The canonical payload written into invalid (NULL) lanes: ``None``
    for object columns, the dtype's zero otherwise. Every backend must
    use the same fill so snapshots/fingerprints do not depend on which
    backend produced a table."""
    return None if dtype == object else np.zeros(1, dtype=dtype)[0]


def payload_validity(values: np.ndarray,
                     valid: np.ndarray | None) -> np.ndarray:
    """Effective validity of a column: the mask AND, for object columns,
    "payload is not None" (freshly-built object columns may carry None
    payloads before any mask exists)."""
    n = len(values)
    ok = (valid.astype(bool, copy=True) if valid is not None
          else np.ones(n, dtype=bool))
    if values.dtype == object:
        ok &= np.array([v is not None for v in values], dtype=bool)
    return ok


def _column_length(cols: Columns) -> int:
    for values, _ in cols.values():
        return len(values)
    return 0


class Backend:
    """One physical implementation of the relational operators.

    Subclasses set ``name`` and implement ``hash_join`` and
    ``group_by_sum``; ``filter_select`` and ``concat`` have shared
    default implementations (plain gather/concatenate — already
    vectorized, and semantics-free enough that the differential suite
    keeps everyone honest)."""

    name: str = "?"

    def cache_token(self) -> str:
        """What the engine folds into node cache keys (DESIGN.md §9/§10).

        The name alone for host backends; backends whose execution
        depends on ambient machine state (device mesh shape, shard
        count, auto-selection policy) must extend it so that state
        change moves every key — a cache hit must never survive a
        regrouping that the float-SUM summation-order carve-out makes
        observable."""
        return self.name

    # -- joins ----------------------------------------------------------
    def hash_join(self, left: Columns, right: Columns,
                  on: Sequence[str], how: str = "inner") -> Columns:
        raise NotImplementedError

    def masked_hash_join(self, left: Columns, right: Columns,
                         on: Sequence[str], how: str = "inner", *,
                         left_mask: "np.ndarray | None" = None,
                         right_mask: "np.ndarray | None" = None
                         ) -> Columns:
        """Filter-fused join. SEMANTICS (normative, what every override
        must reproduce bit for bit): filter each masked side with
        ``filter_select``, then ``hash_join`` the survivors. This
        default IS that definition — the reference backend inherits it
        unchanged, so the differential suite pins the fused paths
        (vectorized key-validity ANDing, the sharded backend's in-VMEM
        Pallas mask) to materialized filtering.

        Equivalence fine print: a fused implementation may produce an
        all-True validity array where this default produces ``None``
        (or vice versa) — the Table layer's ``_ColumnData`` normalizes
        all-True masks to ``None``, so the two are one representation
        by the time anything observable (fingerprint, snapshot) sees
        them. Masks are plain boolean keep-masks over the *unfiltered*
        inputs; ``None`` means keep everything.
        """
        if left_mask is not None:
            left = self.filter_select(left, left_mask)
        if right_mask is not None:
            right = self.filter_select(right, right_mask)
        return self.hash_join(left, right, on, how)

    # -- aggregation ----------------------------------------------------
    def group_by_sum(self, cols: Columns, keys: Sequence[str],
                     value: str, out: str) -> Columns:
        raise NotImplementedError

    # -- row selection --------------------------------------------------
    def filter_select(self, cols: Columns, mask: np.ndarray) -> Columns:
        mask = np.asarray(mask, dtype=bool)
        return {
            name: (values[mask],
                   None if valid is None else valid[mask])
            for name, (values, valid) in cols.items()}

    # -- concatenation --------------------------------------------------
    def concat(self, a: Columns, b: Columns) -> Columns:
        if set(a) != set(b):
            raise ValueError("column sets differ")
        out: dict[str, tuple[np.ndarray, np.ndarray | None]] = {}
        for name, (av, avalid) in a.items():
            bv, bvalid = b[name]
            values = np.concatenate([av, bv])
            if avalid is None and bvalid is None:
                valid = None
            else:
                la = (avalid if avalid is not None
                      else np.ones(len(av), dtype=bool))
                rb = (bvalid if bvalid is not None
                      else np.ones(len(bv), dtype=bool))
                valid = np.concatenate([la, rb])
            out[name] = (values, valid)
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.name!r}>"
