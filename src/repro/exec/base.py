"""Execution-backend interface for the columnar table layer (DESIGN.md §9).

A backend implements the four physical operators the relational layer
dispatches (:class:`~repro.data.tables.Table` stays the only public
API): ``hash_join``, ``group_by_agg``, ``filter_select`` and ``concat``.
Backends operate on *column dicts* — ``{name: (values, valid)}`` with
numpy value arrays and optional boolean validity masks — rather than on
:class:`Table` itself, so the package has no import cycle with the
table layer and a backend can be exercised (and differentially tested)
without building tables.

Semantics are fixed by the ``reference`` backend (the extracted
row-loop implementation): every registered backend must agree with it
bit-for-bit — including NULL handling, row order, and the typed fill
payloads it writes into invalid lanes (fills are hashed by
``Table.fingerprint``, so "don't care" lanes still have to match).
One documented carve-out: *float* SUM and MEAN results are
deterministic per backend but exact only up to summation order across
backends (SIMD / device reductions regroup additions, and MEAN is
finalized from a float sum; no engine promises bit-stable float
aggregation across execution strategies). Integer sums have no
carve-out — integer addition is associative even under wraparound —
and MIN/MAX/COUNT have none either (order-independent reductions).
``tests/test_exec_backends.py`` enforces all of this differentially.

Shared NULL conventions (SQL semantics, established in PR 2):

- join keys: a NULL key matches nothing (``NULL = NULL`` is not TRUE);
  NaN/NaT keys also match nothing (Python/numpy equality agrees);
- GROUP BY keys: all NULL keys form ONE group; NaN keys are pairwise
  distinct (NaN != NaN), so each NaN-keyed row is its own group;
- SUM/MIN/MAX/MEAN skip NULL values; a group whose values are all NULL
  aggregates to NULL. COUNT counts non-NULL values and is never NULL
  (an all-NULL group counts 0). A NaN *value* (valid lane) propagates
  through MIN/MAX (numpy ``minimum``/``maximum`` semantics).
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

__all__ = ["Columns", "Backend", "fill_value", "payload_validity",
           "AGG_FNS", "AggSpec", "normalize_agg_specs"]

# {column name: (values, validity-or-None)} — insertion order is column
# order. `valid is None` means "no NULLs" (the Table-layer convention).
Columns = Mapping[str, tuple[np.ndarray, "np.ndarray | None"]]


def fill_value(dtype: np.dtype):
    """The canonical payload written into invalid (NULL) lanes: ``None``
    for object columns, the dtype's zero otherwise. Every backend must
    use the same fill so snapshots/fingerprints do not depend on which
    backend produced a table."""
    return None if dtype == object else np.zeros(1, dtype=dtype)[0]


def payload_validity(values: np.ndarray,
                     valid: np.ndarray | None) -> np.ndarray:
    """Effective validity of a column: the mask AND, for object columns,
    "payload is not None" (freshly-built object columns may carry None
    payloads before any mask exists)."""
    n = len(values)
    ok = (valid.astype(bool, copy=True) if valid is not None
          else np.ones(n, dtype=bool))
    if values.dtype == object:
        ok &= np.array([v is not None for v in values], dtype=bool)
    return ok


def _column_length(cols: Columns) -> int:
    for values, _ in cols.values():
        return len(values)
    return 0


# The aggregate vocabulary every backend must implement. MEAN is always
# finalized from SUM and COUNT (float64 for numeric values) so the
# sharded backend can ship partials; COUNT is COUNT(value) — non-NULL
# lanes — int64 and never NULL.
AGG_FNS = ("sum", "count", "min", "max", "mean")

# One aggregate: (fn, value column, output column).
AggSpec = tuple[str, str, str]


def normalize_agg_specs(cols: Columns, keys: Sequence[str],
                        specs: Sequence[AggSpec]) -> tuple[AggSpec, ...]:
    """Validate one ``group_by_agg`` call (shared by every backend).

    Checks fn vocabulary, value-column existence, and output-name
    collisions (against the group keys and between specs). Returns the
    specs as a plain tuple so backends can hash/iterate it freely."""
    out: list[AggSpec] = []
    seen: set[str] = set(keys)
    for spec in specs:
        fn, value, name = spec
        if fn not in AGG_FNS:
            raise ValueError(
                f"unknown aggregate fn {fn!r} (expected one of {AGG_FNS})")
        if value not in cols:
            raise KeyError(f"unknown aggregate value column: {value!r}")
        if name in seen:
            raise ValueError(
                f"aggregate output column {name!r} collides with a "
                f"group key or another aggregate output")
        seen.add(name)
        out.append((fn, value, name))
    if not out:
        raise ValueError("group_by_agg requires at least one spec")
    return tuple(out)


class Backend:
    """One physical implementation of the relational operators.

    Subclasses set ``name`` and implement ``hash_join`` and
    ``group_by_agg``; ``filter_select`` and ``concat`` have shared
    default implementations (plain gather/concatenate — already
    vectorized, and semantics-free enough that the differential suite
    keeps everyone honest)."""

    name: str = "?"

    def cache_token(self) -> str:
        """What the engine folds into node cache keys (DESIGN.md §9/§10).

        The name alone for host backends; backends whose execution
        depends on ambient machine state (device mesh shape, shard
        count, auto-selection policy) must extend it so that state
        change moves every key — a cache hit must never survive a
        regrouping that the float-SUM summation-order carve-out makes
        observable."""
        return self.name

    # -- joins ----------------------------------------------------------
    def hash_join(self, left: Columns, right: Columns,
                  on: Sequence[str], how: str = "inner") -> Columns:
        raise NotImplementedError

    def masked_hash_join(self, left: Columns, right: Columns,
                         on: Sequence[str], how: str = "inner", *,
                         left_mask: "np.ndarray | None" = None,
                         right_mask: "np.ndarray | None" = None
                         ) -> Columns:
        """Filter-fused join. SEMANTICS (normative, what every override
        must reproduce bit for bit): filter each masked side with
        ``filter_select``, then ``hash_join`` the survivors. This
        default IS that definition — the reference backend inherits it
        unchanged, so the differential suite pins the fused paths
        (vectorized key-validity ANDing, the sharded backend's in-VMEM
        Pallas mask) to materialized filtering.

        Equivalence fine print: a fused implementation may produce an
        all-True validity array where this default produces ``None``
        (or vice versa) — the Table layer's ``_ColumnData`` normalizes
        all-True masks to ``None``, so the two are one representation
        by the time anything observable (fingerprint, snapshot) sees
        them. Masks are plain boolean keep-masks over the *unfiltered*
        inputs; ``None`` means keep everything.
        """
        if left_mask is not None:
            left = self.filter_select(left, left_mask)
        if right_mask is not None:
            right = self.filter_select(right, right_mask)
        return self.hash_join(left, right, on, how)

    # -- aggregation ----------------------------------------------------
    def group_by_agg(self, cols: Columns, keys: Sequence[str],
                     specs: Sequence[AggSpec]) -> Columns:
        """Multi-function GROUP BY: one output row per distinct key
        tuple (first-appearance order, the reference backend's dict
        order), key columns first, then one column per ``(fn, value,
        out)`` spec. NULL semantics per the module docstring."""
        raise NotImplementedError

    def group_by_sum(self, cols: Columns, keys: Sequence[str],
                     value: str, out: str) -> Columns:
        """Back-compat single-SUM entry point — now a thin delegation
        to ``group_by_agg`` (pinned byte-identical to the pre-refactor
        path by the regression suite)."""
        return self.group_by_agg(cols, keys, (("sum", value, out),))

    # -- row selection --------------------------------------------------
    def filter_select(self, cols: Columns, mask: np.ndarray) -> Columns:
        mask = np.asarray(mask, dtype=bool)
        return {
            name: (values[mask],
                   None if valid is None else valid[mask])
            for name, (values, valid) in cols.items()}

    # -- concatenation --------------------------------------------------
    def concat(self, a: Columns, b: Columns) -> Columns:
        if set(a) != set(b):
            raise ValueError("column sets differ")
        out: dict[str, tuple[np.ndarray, np.ndarray | None]] = {}
        for name, (av, avalid) in a.items():
            bv, bvalid = b[name]
            values = np.concatenate([av, bv])
            if avalid is None and bvalid is None:
                valid = None
            else:
                la = (avalid if avalid is not None
                      else np.ones(len(av), dtype=bool))
                rb = (bvalid if bvalid is not None
                      else np.ones(len(bv), dtype=bool))
                valid = np.concatenate([la, rb])
            out[name] = (values, valid)
        return out

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.name!r}>"
