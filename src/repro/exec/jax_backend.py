"""JAX execution backend: segment-sum aggregation on the accelerator.

Inherits the vectorized backend's join/filter/concat and key
factorization (host-side, numpy) — including the filter-fused
``masked_hash_join`` (key-validity ANDing), so the optimizer's
probe-fusion rewrite benefits this backend with no code here — and
overrides only the aggregation inner loops: per-group SUM/MEAN run
through :func:`repro.kernels.segment_sum.ops.masked_segment_sum` and
MIN/MAX through :func:`~repro.kernels.segment_sum.ops.
masked_segment_reduce` — XLA segment ops by default, or the Pallas
kernels when constructed with ``use_pallas=True``
(env ``REPRO_SEGSUM_PALLAS=1``).

Exactness contract with the ``reference`` oracle:

- integer dtypes are bit-exact (integer addition is associative, even
  under wraparound), so the differential suite holds bit-for-bit;
- float sums are exact up to summation order (device reductions are
  not sequential) — tests compare float sums with tolerance;
- dtypes the device cannot represent faithfully fall back to the
  vectorized numpy path: object columns always, and 64-bit numerics
  whenever ``jax_enable_x64`` is off (the default — silently truncating
  int64 to int32 would be a correctness bug, not a speedup).
"""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.exec.base import fill_value
from repro.exec.vectorized import VectorizedBackend
from repro.kernels import fallback
from repro.kernels.segment_sum.ops import (masked_segment_reduce,
                                           masked_segment_sum)

__all__ = ["JaxBackend"]


class JaxBackend(VectorizedBackend):
    name = "jax"

    def __init__(self, *, use_pallas: bool | None = None,
                 interpret: bool | None = None):
        if use_pallas is None:
            use_pallas = os.environ.get("REPRO_SEGSUM_PALLAS") == "1"
        if interpret is None:
            # CPU containers interpret; real TPUs compile.
            interpret = jax.default_backend() == "cpu"
        self.use_pallas = use_pallas
        self.interpret = interpret

    def cache_token(self) -> str:
        # device reductions regroup float SUMs (the documented
        # carve-out), and the Pallas kernel tiles differently from XLA
        # scatter-add — both are summation-order state a cache hit must
        # not survive.
        suffix = "+pallas" if self.use_pallas else ""
        return f"{self.name}{suffix}[devices={len(jax.devices())}]"

    def _supported(self, dtype: np.dtype) -> bool:
        """Route through the shared numpy-fallback plumbing
        (kernels.fallback): a 64-bit dtype that cannot lower because
        ``jax_enable_x64`` is off warns ONCE naming the env fix —
        degraded perf used to be silent (the whole op quietly ran the
        numpy path)."""
        if not fallback.device_supports_dtype(dtype):
            if fallback.x64_is_the_fix(dtype):
                fallback.warn_numpy_fallback(
                    f"{self.name}.group_by_agg", dtype)
            return False
        return True

    @staticmethod
    def _segment_ids(order: np.ndarray, bounds: np.ndarray,
                     grp_order: np.ndarray, n_groups: int,
                     n: int) -> np.ndarray:
        """Per-row segment ids in output (first-appearance) order, from
        the group-run structure the vectorized base already computed."""
        run_lengths = np.diff(np.r_[bounds, n])
        inv_code = np.empty(n, dtype=np.int64)
        inv_code[order] = np.repeat(np.arange(n_groups), run_lengths)
        rank = np.empty(n_groups, dtype=np.int64)
        rank[grp_order] = np.arange(n_groups)
        return rank[inv_code]

    def _aggregate(self, values: np.ndarray, ok: np.ndarray,
                   order: np.ndarray, bounds: np.ndarray,
                   grp_order: np.ndarray, n_groups: int
                   ) -> tuple[np.ndarray, np.ndarray]:
        if n_groups == 0 or not self._supported(values.dtype):
            return super()._aggregate(values, ok, order, bounds,
                                      grp_order, n_groups)
        gid = self._segment_ids(order, bounds, grp_order, n_groups,
                                len(values))
        sums, counts = masked_segment_sum(
            jnp.asarray(values), jnp.asarray(gid.astype(np.int32)),
            jnp.asarray(ok), n_groups,
            use_pallas=self.use_pallas, interpret=self.interpret)
        # empty segments already hold 0 == the canonical numeric fill
        return (np.asarray(sums).astype(values.dtype, copy=False),
                np.asarray(counts) > 0)

    def _agg_minmax(self, fn: str, values: np.ndarray, ok: np.ndarray,
                    order: np.ndarray, bounds: np.ndarray,
                    grp_order: np.ndarray, n_groups: int
                    ) -> tuple[np.ndarray, np.ndarray]:
        vdt = values.dtype
        if (n_groups == 0 or vdt == object or vdt.kind not in "fiu"
                or not self._supported(vdt)):
            return super()._agg_minmax(fn, values, ok, order, bounds,
                                       grp_order, n_groups)
        gid = self._segment_ids(order, bounds, grp_order, n_groups,
                                len(values))
        red, counts = masked_segment_reduce(
            jnp.asarray(values), jnp.asarray(gid.astype(np.int32)),
            jnp.asarray(ok), n_groups, op=fn,
            use_pallas=self.use_pallas, interpret=self.interpret)
        # empty segments hold the reduce identity (±inf / dtype
        # extremes), not the canonical fill — rewrite them.
        red = np.array(red).astype(vdt, copy=False)
        has = np.asarray(counts) > 0
        red[~has] = fill_value(vdt)
        return red, has
