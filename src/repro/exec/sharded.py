"""Shard-aware distributed hash join across the JAX device mesh.

Extends the ``jax`` backend (which already runs aggregation through
``kernels/segment_sum``) with a mesh-parallel ``hash_join``: the join
inner loop — the dominant cost of every pipeline wave — is partitioned
over a 1-D ``("shard",)`` mesh so each device owns one key range and
probes only its cache-resident slice, instead of the vectorized
backend's whole-table binary search whose every step misses cache at
1e6+ rows. DESIGN.md §10.

Division of labor (host steps are numpy, device steps run under
``shard_map``):

1. **Key coding** (host). Single same-kind integer keys are rebased to
   ``key - min`` and ship raw when the span fits int32 — no
   factorization at all, the sharded twin of the vectorized backend's
   direct-address fast path, except the key space is *distributed*:
   each shard owns ``span/ndev`` of it, so the trick keeps working at
   spans where the single-host bincount heuristic gives up. Everything
   else (multi-column, object, cross-kind, wide-span keys) goes
   through the existing joint factorization
   (``vectorized._join_codes``) to dense codes — the factorization IS
   the hash, so the per-shard slot space is perfect (collision-free).
   64-bit keys that cannot lower because ``jax_enable_x64`` is off
   degrade to the vectorized backend through the shared
   ``kernels.fallback`` plumbing — loudly, not silently. Unmatchable
   rows (NULL / NaN keys) are coded to the dtype-max sentinel.
2. **Radix partition** (host). Rows are counting-sorted (a per-chunk
   byte radix pass — no comparison sort anywhere on the host path)
   into ``(src_device, owner_shard, capacity)`` slabs — owner =
   contiguous key range, or a mixing hash for wide-span raw keys.
   Capacity is exact (one bincount), so the exchange can never
   overflow; shapes round to powers of two so the jit cache stays
   small. The host keeps the permutation, so devices exchange *keys
   only* and results map back with pure index arithmetic.
3. **all_to_all + per-shard probe** (device). A tiled ``all_to_all``
   turns the src-major slabs into owner-major rows (arrival order ==
   global row order — this is what preserves the reference's
   right-occurrence order). Each shard sorts its build keys (one
   single-operand sort; sentinels sink to the end) and emits per probe
   lane the (start, count) of its match run. Two probe strategies:

   - default: two ``searchsorted`` passes over the shard-local sorted
     run — with build sides deduplicated by construction (the common
     FK shape, detected on device by an adjacent-equal scan) the
     grouped layout is the sorted order itself and per-lane ranks come
     from one more binary search; duplicate build keys take a
     ``lax.cond`` branch that stable-sorts (key, arrival) pairs
     instead.
   - ``REPRO_HASHJOIN_PALLAS=1`` (the TPU compile target): build the
     open-addressing (start, count) direct-address table over the
     shard's slot range and probe it through ``kernels/hash_join`` —
     the Pallas one-hot probe kernel, or its XLA gather oracle under
     ``interpret``-less CPU runs. Mirrors ``kernels/segment_sum``:
     the kernel is the accelerator path, the host default is whatever
     measures fastest there.
4. **Ragged emission** (host). Per-shard (start, count) pairs are
   offset by the shard's stride, scattered back to original left row
   order through the kept permutation, and expanded by the vectorized
   backend's ``_emit_join`` — which is what makes the output
   bit-for-bit identical to ``reference``, row order included.

Aggregation (PR 7) moves onto the mesh too: ``group_by_agg`` runs
per-shard *partial* aggregation under ``shard_map`` BEFORE the
``all_to_all`` exchange. Each shard reduces its local rows to one
partial stat vector per (distinct key, needed stat), so the exchange
ships one lane per (shard, key slot) instead of one per input row;
the key's owner shard combines the partials (add for SUM/COUNT,
min/max for MIN/MAX), and MEAN is finalized from the shipped
sum+count after the exchange — it is never shipped as a value. The
per-shard reduction mirrors the join's two probe strategies: <= 32-bit
integer values take a packed single-operand sort (counts/sums/min/max
all fall out of run boundaries — no scatter, which XLA:CPU serializes
per row), while float and 64-bit values, plus the ``use_pallas`` TPU
target, run the masked ``kernels/segment_sum`` family (NaN
propagation baked into each partial). First-appearance output order
never rides the exchange at all: the host already materialized the
dense slot codes for the rebase, so one reversed fancy assignment
recovers each slot's first row and one small argsort over distinct
keys (never over rows) orders the output. Eligibility mirrors the
join's direct-address fast path (single integer key, affordable span,
device-lowerable value dtypes); everything else falls back to the
inherited jax/vectorized path. Filter and concat stay inherited.
"""
from __future__ import annotations

import contextlib
import functools
import os
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_map
from repro.exec.base import (AggSpec, Columns, _column_length, fill_value,
                             normalize_agg_specs, payload_validity)
from repro.exec.jax_backend import JaxBackend
from repro.exec.vectorized import (_and_key_validity, _join_codes,
                                   dense_span_affordable)
from repro.kernels import fallback
from repro.kernels.hash_join.ops import hash_probe, masked_hash_probe
from repro.kernels.segment_sum.ops import (masked_segment_reduce,
                                           masked_segment_sum)
from repro.kernels.segment_sum.ref import reduce_identity
from repro.obs import get_recorder

__all__ = ["ShardedBackend"]

# Key spans up to this use contiguous-range partitioning with a
# power-of-two per-shard slot space ("table" mode — required for the
# Pallas direct-address path; also keeps the bucket computation a pure
# shift with the dtype-max sentinel safely out of shard range). Wider
# key spaces hash-partition ("hash" mode); anything that fits int32
# still ships as int32.
MAX_TABLE_SPAN = 1 << 26

_NOOP_CTX = contextlib.nullcontext()


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def _round_cap(n: int) -> int:
    """Slab capacity rounding: up to the next multiple of the value's
    third-highest bit — at most 12.5% padding (a pure power of two
    wastes up to 2x at awkward sizes), while keeping the set of
    distinct jit shapes small."""
    n = max(int(n), 64)
    gran = max(64, 1 << (n.bit_length() - 3))
    return -(-n // gran) * gran


def _mix32(h: np.ndarray) -> np.ndarray:
    """Deterministic int32 mixing hash (wraparound multiply)."""
    h = h ^ (h >> np.int32(16))
    with np.errstate(over="ignore"):
        h = (h * np.int32(0x45D9F3B)).astype(np.int32)
    h = h ^ (h >> np.int32(13))
    return h & np.int32(0x7FFFFFFF)


@functools.lru_cache(maxsize=None)
def _get_mesh(ndev: int):
    return jax.make_mesh((ndev,), ("shard",),
                         devices=jax.devices()[:ndev])


@functools.lru_cache(maxsize=64)
def _probe_fn(ndev: int, cap_l: int, cap_r: int, span_shard: int,
              np_dtype: str, use_pallas: bool, interpret: bool,
              masked: bool = False):
    """Build + jit the shard_map'd exchange-and-probe for one static
    signature. Unmatchable lanes (NULL/NaN keys and slab padding)
    carry the dtype-max sentinel and can match nothing: they sort to
    the end, fall outside every table slot, and are masked out of
    counts. ``span_shard`` > 0 selects the direct-address slot space
    of "table" mode (required for the Pallas path); 0 means wide-span
    raw keys. ``masked`` adds a probe-side keep-mask slab and routes
    through the filter-fused Pallas probe (table mode only — the
    caller host-poisons keys to the sentinel on every other route)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = _get_mesh(ndev)
    dtype = np.dtype(np_dtype)
    sent = dtype.type(np.iinfo(dtype).max)

    def exchange(slab):                  # (1, ndev, cap) -> (ndev*cap,)
        x = jax.lax.all_to_all(slab[0], "shard", split_axis=0,
                               concat_axis=0, tiled=True)
        # src-major flatten: arrival order == global row order, which
        # is what lets the grouped layouts below reproduce the
        # reference's right-occurrence order within a key.
        return x.reshape(-1)

    def probe_packed(lk, rk):
        """Packed-sort strategy for int32 keys (the CPU-mesh default).

        One single-operand sort of ``key << 32 | arrival`` orders the
        build side by key with ties in arrival — i.e. global row —
        order, so the grouped layout AND its arrival translation
        (``gidx``) fall out of the same sort with no stable pair sort,
        no scatter, and no separate duplicate-key path. Sentinel lanes
        (padding / NULL keys) pack highest and sink to the tail. The
        probe is one binary search; the count is a hit-check gather
        when the build keys are unique (the common FK shape) and a
        second binary search otherwise."""
        m = rk.shape[0]
        iota = jnp.arange(m, dtype=jnp.int64)
        packed = (rk.astype(jnp.int64) << 32) | iota
        p_srt = jax.lax.sort(packed)
        k_srt = (p_srt >> 32).astype(jnp.int32)
        gidx = (p_srt & jnp.int64(0xFFFFFFFF)).astype(jnp.int32)
        starts = jnp.searchsorted(k_srt, lk).astype(jnp.int32)
        dup = jnp.any((k_srt[1:] == k_srt[:-1]) & (k_srt[1:] != sent))

        def fast(_):
            hit = (k_srt[jnp.minimum(starts, m - 1)] == lk) \
                & (lk != sent)
            return hit.astype(jnp.int32)

        def slow(_):
            ends = jnp.searchsorted(k_srt, lk, side="right")
            return jnp.where(lk != sent,
                             ends - starts.astype(ends.dtype),
                             0).astype(jnp.int32)

        counts = jax.lax.cond(dup, slow, fast, None)
        return starts, counts, gidx

    def probe_wide(lk, rk):
        """int64 keys (jax_enable_x64 verified upstream): stable
        (key, arrival) pair sort + two binary searches."""
        m = rk.shape[0]
        iota = jnp.arange(m, dtype=jnp.int32)
        k_srt, gidx = jax.lax.sort((rk, iota), num_keys=1,
                                   is_stable=True)
        starts = jnp.searchsorted(k_srt, lk, side="left")
        ends = jnp.searchsorted(k_srt, lk, side="right")
        counts = jnp.where(lk != sent, ends - starts, 0)
        return (starts.astype(jnp.int32), counts.astype(jnp.int32),
                gidx)

    def probe_table(lk, rk, lmask=None):
        """Direct-address strategy (the Pallas/TPU path): build the
        open-addressing (start, count) table over this shard's slot
        range, probe through kernels/hash_join. Grouped layout is
        arrival order (unique) or sorted order (duplicates).
        ``lmask`` (filter-fused probe) zeroes masked lanes inside the
        kernel — the filtered rows never leave VMEM."""
        m = rk.shape[0]
        iota = jnp.arange(m, dtype=jnp.int32)
        base = (jax.lax.axis_index("shard") * span_shard).astype(
            jnp.int32)
        slot_r = rk - base               # sentinel -> far out of range
        slot_l = lk - base
        counts_tab = jnp.zeros(span_shard, jnp.int32).at[slot_r].add(
            1, mode="drop")
        unique = jnp.max(counts_tab, initial=0) <= 1

        def fast(_):
            # unique build keys: the grouped layout IS arrival order;
            # start[slot] = the one arrival position.
            pos_tab = jnp.full(span_shard, -1, jnp.int32).at[
                slot_r].set(iota, mode="drop")
            return pos_tab, iota

        def slow(_):
            # duplicate keys: stable-sort the shard by slot (ties keep
            # arrival == global row order) and scatter-min run starts.
            srt, gidx = jax.lax.sort(
                (jnp.where(rk != sent, slot_r, span_shard), iota),
                num_keys=1, is_stable=True)
            pos_tab = jnp.full(span_shard, m, jnp.int32).at[srt].min(
                jnp.arange(m, dtype=jnp.int32), mode="drop")
            return pos_tab, gidx

        pos_tab, gidx = jax.lax.cond(unique, fast, slow, None)
        if lmask is None:
            starts, counts = hash_probe(pos_tab, counts_tab, slot_l,
                                        use_pallas=use_pallas,
                                        interpret=interpret)
        else:
            starts, counts = masked_hash_probe(
                pos_tab, counts_tab, slot_l, lmask,
                use_pallas=use_pallas, interpret=interpret)
        return starts, counts, gidx

    def body_masked(l_slab, m_slab, r_slab):
        # fused-filter path: selected only for table mode + Pallas, so
        # the probe is always the direct-address kernel with the mask
        # slab riding next to the key slab (same owner-major layout).
        lk = l_slab[0].reshape(-1)
        lmask = m_slab[0].reshape(-1)
        rk = exchange(r_slab)
        starts, counts, gidx = probe_table(lk, rk, lmask)
        return starts[None, :], counts[None, :], gidx[None, :]

    def body(l_slab, r_slab):
        # build side: all_to_all so each device owns every row of its
        # key range. Probe side: the host already laid slabs out
        # owner-major (same src-major arrival order the exchange would
        # produce), so probes just flatten — one collective, not two.
        lk = l_slab[0].reshape(-1)
        rk = exchange(r_slab)
        if use_pallas and span_shard:
            probe = probe_table
        elif dtype.itemsize > 4:
            probe = probe_wide
        else:
            probe = probe_packed
        starts, counts, gidx = probe(lk, rk)
        return starts[None, :], counts[None, :], gidx[None, :]

    spec = P("shard", None, None)
    out = P("shard", None)
    fn = body_masked if masked else body
    in_specs = (spec,) * (3 if masked else 2)
    mapped = shard_map(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=(out, out, out), check_vma=False)
    shard = NamedSharding(mesh, spec)
    return jax.jit(mapped, in_shardings=(shard,) * len(in_specs))


@functools.lru_cache(maxsize=64)
def _partial_agg_fn(ndev: int, seg_shard: int, col_sig: tuple,
                    use_pallas: bool, interpret: bool):
    """Build + jit the shard_map'd partial-aggregation exchange for one
    static signature. ``col_sig`` is a tuple of (dtype str, stats
    tuple) per distinct value column, stats drawn from
    {"sum", "min", "max"} — COUNT partials are always produced (they
    double as output validity and the MEAN divisor).

    Protocol per shard: reduce local rows to (nseg,) partial vectors,
    ``all_to_all`` each vector (one lane per (shard, key slot) — never
    one per row), then the owner shard combines its slot range: add
    for sum/count, min/max for min/max. Two per-column reduction
    strategies, the aggregation twin of the join's packed/table probe
    split:

    - packed (the CPU-mesh default for <= 32-bit integer values): one
      single-operand sort of ``slot << 32 | order-biased value`` —
      counts are run lengths, the sum is a difference of two lanes of
      one wrapping cumsum (modular, so bit-identical to the
      reference), and min/max are the run's first/last element. No
      scatter anywhere: XLA:CPU lowers segment ops to a serial
      per-row scatter that costs ~10x the sort at benchmark shapes.
    - kernels/segment_sum family (``use_pallas`` — the TPU compile
      target — plus float and 64-bit values, whose NaN propagation
      and non-reorderable sums want the masked kernels). NaN
      poisoning is baked into each shard's partial by
      ``masked_segment_reduce``, and jnp.min/max propagate it
      through the combine."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = _get_mesh(ndev)
    nseg = ndev * seg_shard

    def combine(x, mode: str):
        y = jax.lax.all_to_all(x, "shard", split_axis=0,
                               concat_axis=0, tiled=True)
        y = y.reshape(ndev, seg_shard)
        if mode == "sum":
            # dtype pinned: int partial sums must wrap in the value
            # dtype (associative, so bit-identical to the reference),
            # not promote to the platform int.
            return jnp.sum(y, axis=0, dtype=y.dtype)[None, :]
        if mode == "min":
            return jnp.min(y, axis=0)[None, :]
        return jnp.max(y, axis=0)[None, :]

    def reduce_packed(gid, vals, ok, stats, dtype):
        n_rows = gid.shape[0]
        jdt = jnp.dtype(dtype)
        # invalid lanes (and slab padding, which arrives ok=False) go
        # to the dead slot nseg: they sort past every real run and no
        # searchsorted target ever reaches them.
        gg = jnp.where(ok, gid, jnp.int32(nseg)).astype(jnp.int64)
        v64 = vals.astype(jnp.int64)
        if dtype.kind == "u":
            key = v64 & jnp.int64(0xFFFFFFFF)
        else:            # bias bit 31: two's complement -> uint order
            key = (v64 ^ jnp.int64(0x80000000)) & jnp.int64(0xFFFFFFFF)
        p = jax.lax.sort((gg << 32) | key)
        sg = (p >> 32).astype(jnp.int32)
        sk = p & jnp.int64(0xFFFFFFFF)
        if dtype.kind == "u":
            sv = sk.astype(jdt)
        else:            # xor undoes the bias; int32 wrap restores sign
            sv = (sk ^ jnp.int64(0x80000000)).astype(jnp.int32) \
                .astype(jdt)
        slots = jnp.arange(nseg, dtype=jnp.int32)
        starts = jnp.searchsorted(sg, slots, side="left") \
            .astype(jnp.int32)
        ends = jnp.searchsorted(sg, slots, side="right") \
            .astype(jnp.int32)
        cnt = ends - starts
        outs = [combine(cnt, "sum")]
        if "sum" in stats:
            # wrapping cumsum in the value dtype: the boundary
            # difference is the exact modular group sum.
            cs = jnp.cumsum(sv, dtype=jdt)
            zero = jnp.zeros((), jdt)
            tot = jnp.where(ends > 0, cs[jnp.maximum(ends, 1) - 1],
                            zero)
            base = jnp.where(starts > 0, cs[jnp.maximum(starts, 1) - 1],
                             zero)
            outs.append(combine((tot - base).astype(jdt), "sum"))
        if "min" in stats:
            mn = sv[jnp.minimum(starts, n_rows - 1)]
            outs.append(combine(
                jnp.where(cnt > 0, mn,
                          jnp.asarray(reduce_identity(dtype, "min"),
                                      jdt)), "min"))
        if "max" in stats:
            mx = sv[jnp.maximum(ends, 1) - 1]
            outs.append(combine(
                jnp.where(cnt > 0, mx,
                          jnp.asarray(reduce_identity(dtype, "max"),
                                      jdt)), "max"))
        return outs

    def reduce_kernels(gid, vals, ok, stats):
        s, cnt = masked_segment_sum(
            vals, gid, ok, nseg,
            use_pallas=use_pallas, interpret=interpret)
        outs = [combine(cnt, "sum")]
        if "sum" in stats:
            outs.append(combine(s, "sum"))
        for op in ("min", "max"):
            if op in stats:
                r, _ = masked_segment_reduce(
                    vals, gid, ok, nseg, op=op,
                    use_pallas=use_pallas, interpret=interpret)
                outs.append(combine(r, op))
        return outs

    def body(gid_slab, *col_slabs):
        gid = gid_slab[0]
        outs = []
        i = 0
        for dt_str, stats in col_sig:
            dtype = np.dtype(dt_str)
            vals = col_slabs[i][0]
            ok = col_slabs[i + 1][0]
            i += 2
            if (dtype.kind in "iu" and dtype.itemsize <= 4
                    and not use_pallas):
                outs += reduce_packed(gid, vals, ok, stats, dtype)
            else:
                outs += reduce_kernels(gid, vals, ok, stats)
        return tuple(outs)

    spec = P("shard", None)
    n_in = 1 + 2 * len(col_sig)
    mapped = shard_map(body, mesh=mesh, in_specs=(spec,) * n_in,
                       out_specs=spec, check_vma=False)
    shard = NamedSharding(mesh, spec)
    return jax.jit(mapped, in_shardings=(shard,) * n_in)


class ShardedBackend(JaxBackend):
    name = "sharded"

    def __init__(self, *, n_devices: int | None = None,
                 use_pallas: bool | None = None,
                 use_pallas_probe: bool | None = None,
                 interpret: bool | None = None):
        super().__init__(use_pallas=use_pallas, interpret=interpret)
        if use_pallas_probe is None:
            use_pallas_probe = os.environ.get(
                "REPRO_HASHJOIN_PALLAS") == "1"
        self.use_pallas_probe = use_pallas_probe
        self.n_devices = (n_devices if n_devices is not None
                          else len(jax.devices()))

    # cache-key interaction (DESIGN.md §10): a mesh change regroups row
    # placement (and, through the inherited device aggregation, float
    # SUM summation order under the documented carve-out), so the shard
    # count must move every engine cache key — and so must the
    # inherited segment-sum Pallas flag, whose tiling regroups float
    # sums too. The probe strategy flag is deliberately absent: probe
    # outputs are integer-exact identical across strategies.
    def cache_token(self) -> str:
        suffix = "+pallas" if self.use_pallas else ""
        return f"{self.name}{suffix}[devices={self.n_devices}]"

    # -- join -----------------------------------------------------------
    def hash_join(self, left: Columns, right: Columns,
                  on: Sequence[str], how: str = "inner") -> Columns:
        return self._sharded_join(left, right, on, how, None)

    def masked_hash_join(self, left: Columns, right: Columns,
                         on: Sequence[str], how: str = "inner", *,
                         left_mask: "np.ndarray | None" = None,
                         right_mask: "np.ndarray | None" = None
                         ) -> Columns:
        """Filter-fused distributed join. The right mask folds into the
        key validity on the host before coding (masked build rows code
        to the sentinel and land in the drop bucket — they never ship).
        The left (probe) mask rides to the device as a slab and is
        applied *inside* the Pallas probe kernel when table mode is
        active — the filtered rows never leave VMEM; every other route
        host-poisons the coded keys to the sentinel, which the existing
        sentinel machinery drops for free. ``how='left'`` with a left
        mask must prefilter (a masked row must not emit as unmatched).
        """
        if left_mask is not None and how != "inner":
            left = self.filter_select(left, left_mask)
            left_mask = None
        if right_mask is not None:
            right = _and_key_validity(right, on, right_mask)
        return self._sharded_join(left, right, on, how, left_mask)

    def _host_fallback(self, left: Columns, right: Columns,
                       on: Sequence[str], how: str,
                       probe_mask: "np.ndarray | None", *,
                       reason: str = "keys cannot lower") -> Columns:
        # sharded -> vectorized downgrade: structured degradation event
        # so run manifests show it (the dtype-driven routes ALSO warn
        # one-time via fallback.warn_numpy_fallback upstream).
        rec = get_recorder()
        if rec.enabled:
            rec.event("degradation", kind="sharded_downgrade",
                      op="hash_join", reason=reason)
            rec.metrics.counter("sharded.downgrades").inc()
        if probe_mask is None:
            return super().hash_join(left, right, on, how)
        return super().masked_hash_join(left, right, on, how,
                                        left_mask=probe_mask)

    def _sharded_join(self, left: Columns, right: Columns,
                      on: Sequence[str], how: str,
                      probe_mask: "np.ndarray | None") -> Columns:
        n_left = _column_length(left)
        n_right = _column_length(right)
        ndev = max(1, self.n_devices)
        if n_left == 0 or n_right == 0:
            return self._host_fallback(left, right, on, how, probe_mask,
                                       reason="empty input side")
        if n_left >= 2**31 or n_right >= 2**31:
            return self._host_fallback(left, right, on, how, probe_mask,
                                       reason="row count exceeds int32")
        if ndev > 255:                  # buckets are uint8
            return self._host_fallback(
                left, right, on, how, probe_mask,
                reason=f"{ndev} devices exceeds the uint8 bucket space "
                       f"(255)")

        keyed = self._device_keys(left, right, on)
        if keyed is None:               # cannot lower: vectorized path
            return self._host_fallback(
                left, right, on, how, probe_mask,
                reason="keys cannot lower to the device without losing "
                       "bits")
        lk, rk, span = keyed
        if span == 0:                   # no valid key anywhere
            if probe_mask is not None and how != "inner":
                left = self.filter_select(left, probe_mask)
                n_left = _column_length(left)
            return self._emit_join(
                left, right, how, n_left,
                np.zeros(n_left, np.int64), np.zeros(n_left, np.int64),
                np.array([], dtype=np.int64))
        # power-of-two per-shard slot space: buckets become a shift and
        # the dtype-max sentinel lands safely past the last shard.
        span_shard = (_next_pow2(-(-span // ndev))
                      if 0 < span <= MAX_TABLE_SPAN else 0)

        # fused-filter dispatch: table mode + Pallas keeps the mask on
        # the device (in-VMEM); every other route poisons masked lanes
        # to the sentinel here — they bucket to the drop lane and never
        # even ship.
        fused = (probe_mask is not None and self.use_pallas_probe
                 and span_shard > 0)
        if probe_mask is not None and not fused:
            sent = lk.dtype.type(np.iinfo(lk.dtype).max)
            lk = np.where(np.asarray(probe_mask, dtype=bool), lk, sent)

        lb = _buckets(lk, ndev, span_shard)
        rb = _buckets(rk, ndev, span_shard)
        l_slab, l_idx, cap_l = _partition(lk, lb, ndev)
        r_slab, r_idx, cap_r = _partition(rk, rb, ndev)
        if ndev * cap_l >= 2**31 or ndev * cap_r >= 2**31:
            # padded per-shard lane counts must fit the int32 arrival
            # positions the probes pack — possible past ~2e9 rows with
            # heavy bucket skew even though the raw row counts passed
            # the guard above.
            return self._host_fallback(
                left, right, on, how, probe_mask,
                reason="padded slab lanes exceed int32 arrival space "
                       "(bucket skew)")
        # probe side ships owner-major (src stays the minor axis, so
        # per-device arrival order matches what the build side's
        # all_to_all produces).
        l_slab = np.ascontiguousarray(l_slab.transpose(1, 0, 2))

        fn = _probe_fn(ndev, cap_l, cap_r, span_shard, lk.dtype.str,
                       self.use_pallas_probe, self.interpret,
                       masked=fused)
        if fused:
            keep = np.asarray(probe_mask, dtype=bool)
            m_slab = np.where(
                l_idx >= 0, keep[np.clip(l_idx, 0, None)], False
            ).astype(np.int32)
            m_slab = np.ascontiguousarray(m_slab.transpose(1, 0, 2))
            args = (l_slab, m_slab, r_slab)
        else:
            args = (l_slab, r_slab)
        rec = get_recorder()
        kernel_ctx = _NOOP_CTX
        if rec.enabled:
            # every slab in `args` crosses the mesh through all_to_all
            bytes_moved = sum(a.nbytes for a in args)
            kernel_ctx = rec.span(
                "kernel", op="sharded.exchange_probe", ndev=ndev,
                mode=("table" if span_shard > 0 else "hash"),
                fused_mask=fused, all_to_all_bytes=bytes_moved,
                rows_left=n_left, rows_right=n_right)
            rec.metrics.histogram(
                "sharded.all_to_all_bytes").observe(bytes_moved)
        # the packed/wide probes carry int64 intermediates; the x64
        # scope is thread-local and only governs types traced inside.
        with kernel_ctx:
            with jax.experimental.enable_x64():
                out = fn(*args)
        starts, counts, gidx = (np.asarray(o) for o in out)

        # map device results back through the kept permutation: the
        # grouped layout is the per-shard arrival order permuted by
        # gidx, and arrival order is the host's own slab layout — so
        # the translation to global row ids is one gather, and padding
        # arrival cells (-1) become holes the emission never reads.
        # Per-key runs are contiguous on exactly one shard, so
        # concatenating shard layouts (stride = ndev*cap_r) is a valid
        # grouped layout for the shared ragged emission.
        stride = ndev * cap_r
        arr_l = l_idx.transpose(1, 0, 2).reshape(ndev, ndev * cap_l)
        arr_r = r_idx.transpose(1, 0, 2).reshape(ndev, stride)
        ridx = np.take_along_axis(
            arr_r, gidx.astype(np.int64, copy=False), axis=1
        ).reshape(-1)
        # int64 accumulators: the ragged emission cumsums counts, and
        # a >2**31-row join output must not wrap there.
        starts_g = np.zeros(n_left, np.int64)
        counts_g = np.zeros(n_left, np.int64)
        m = arr_l >= 0
        starts_g[arr_l[m]] = (starts.astype(np.int64)
                              + (np.arange(ndev, dtype=np.int64)
                                 * stride)[:, None])[m]
        counts_g[arr_l[m]] = counts[m]
        return self._emit_join(left, right, how, n_left, starts_g,
                               counts_g,
                               ridx.astype(np.int64, copy=False))

    # -- key coding ------------------------------------------------------
    def _device_keys(self, left: Columns, right: Columns,
                     on: Sequence[str]):
        """(lkeys, rkeys, span) with unmatchable rows already coded to
        the dtype-max sentinel; span > 0 = int32 slot codes ("table"
        mode), span < 0 = raw keys, hash partition ("hash" mode);
        span == 0 = no valid keys at all. None when the keys cannot
        lower to the device without losing bits (the shared
        numpy-fallback plumbing warns)."""
        raw = self._raw_int_keys(left, right, on)
        if raw is not None:
            return raw
        lcodes, rcodes = _join_codes(left, right, on)
        card = int(max(lcodes.max(initial=-1),
                       rcodes.max(initial=-1))) + 1
        if card == 0:
            return lcodes.astype(np.int32), rcodes.astype(np.int32), 0
        if card >= 2**31 - 64:
            # row counts are int32-checked upstream, so a cardinality
            # past the int32 code space is unreachable in practice —
            # keep the guard anyway (codes must fit int32 + sentinel).
            fallback.warn_numpy_fallback(
                "sharded.hash_join", np.dtype(np.int64),
                reason="joint key cardinality exceeds the int32 code "
                       "space")
            return None
        sent = np.int32(np.iinfo(np.int32).max)
        lk = lcodes.astype(np.int32)
        rk = rcodes.astype(np.int32)
        lk[lk < 0] = sent
        rk[rk < 0] = sent
        return lk, rk, card

    def _raw_int_keys(self, left: Columns, right: Columns,
                      on: Sequence[str]):
        """Single same-kind integer key: ship rebased raw values (numpy
        equality == Python equality for int kinds), skipping
        factorization — the sharded twin of the vectorized
        direct-address fast path, distributed so it scales past the
        single-host span budget."""
        if len(on) != 1:
            return None
        lv, lval = left[on[0]]
        rv, rval = right[on[0]]
        if (lv.dtype == object or rv.dtype == object
                or lv.dtype.kind not in "iu"
                or lv.dtype.kind != rv.dtype.kind):
            return None
        lok = payload_validity(lv, lval)
        rok = payload_validity(rv, rval)
        if not lok.any() or not rok.any():
            return None                   # codes path handles trivially
        lo = min(int(lv[lok].min()), int(rv[rok].min()))
        hi = max(int(lv[lok].max()), int(rv[rok].max()))
        span = hi - lo + 1
        sent32 = np.int32(np.iinfo(np.int32).max)
        if (0 <= lo and hi < 2**31 - 64
                and (hi < MAX_TABLE_SPAN or span > MAX_TABLE_SPAN)):
            # values are already valid int32 slot codes — no rebase
            # pass; span = hi+1 keeps shard 0 a touch wider, which the
            # exact capacity computation absorbs. NOT taken when only
            # the rebased span fits the table budget (dense-but-offset
            # keys): the shortcut must never cost table mode — and
            # with it the Pallas probe path — that the rebase below
            # would keep.
            lk = lv.astype(np.int32)
            rk = rv.astype(np.int32)
            lk[~lok] = sent32
            rk[~rok] = sent32
            return lk, rk, hi + 1
        if span <= 2**31 - 64:
            # rebase to slot codes: the distributed key space absorbs
            # the sparsity (span/ndev slots per shard). Two exact
            # routes: uint64 subtracts in its native dtype (lo is the
            # joint min, so no wrap — an int64 intermediate would
            # overflow past 2**63); every other kind widens to int64
            # first (native-width subtraction would wrap int8/int16
            # spans, and lo — the min across BOTH sides, possibly a
            # wider dtype — need not fit the narrow dtype at all).
            # Either way the rebased values are < span < 2**31.
            def rebase(v):
                if v.dtype.kind == "u" and v.dtype.itemsize == 8:
                    return (v - v.dtype.type(lo)).astype(np.int32)
                return (v.astype(np.int64) - lo).astype(np.int32)

            lk = rebase(lv)
            rk = rebase(rv)
            lk[~lok] = sent32
            rk[~rok] = sent32
            return lk, rk, span
        if -2**63 <= lo and hi <= 2**63 - 2:
            if not fallback.device_supports_dtype(np.dtype(np.int64)):
                # NOT a whole-op fallback: the join still runs on the
                # mesh through factorized int32 codes — what degrades
                # is the key coding (a host np.unique pass replaces
                # shipping raw int64 keys). Warn with the accurate
                # scope, still naming the env fix.
                fallback.warn_numpy_fallback(
                    "sharded.hash_join", lv.dtype,
                    reason="wide-span 64-bit keys take the host "
                           "factorization path; enable jax_enable_x64 "
                           "(e.g. JAX_ENABLE_X64=1) to ship raw int64 "
                           "keys to the device")
                return None               # codes path (still sharded)
            sent = np.int64(np.iinfo(np.int64).max)
            lk = lv.astype(np.int64)
            rk = rv.astype(np.int64)
            lk[~lok] = sent
            rk[~rok] = sent
            return lk, rk, -1
        return None                       # uint64 tail: codes path

    # -- aggregation -----------------------------------------------------
    def group_by_agg(self, cols: Columns, keys: Sequence[str],
                     specs: Sequence[AggSpec]) -> Columns:
        specs = normalize_agg_specs(cols, keys, specs)
        partial = self._partial_group_by(cols, keys, specs)
        if partial is not None:
            return partial
        return super().group_by_agg(cols, keys, specs)

    def _partial_group_by(self, cols: Columns, keys: Sequence[str],
                          specs: tuple[AggSpec, ...]
                          ) -> "Columns | None":
        """Mesh partial-aggregation path; None when ineligible (the
        inherited jax/vectorized path takes over). Eligibility mirrors
        the join's direct-address fast path: one integer-kind key whose
        span is dense enough to direct-address, every value column
        device-lowerable. NULL keys take one extra slot (SQL: one NULL
        group); integer keys cannot be NaN, so slots are exact."""
        n = _column_length(cols)
        ndev = max(1, self.n_devices)
        if n == 0 or n >= 2**31 - 2 or ndev > 255 or len(keys) != 1:
            return None
        kv, kvalid = cols[keys[0]]
        if kv.dtype == object or kv.dtype.kind not in "iu":
            return None
        # every value column must lower losslessly (the 64-bit-off
        # fallback warns in the inherited path, not here)
        want: dict[str, set] = {}
        for fn, value, _out in specs:
            vdt = cols[value][0].dtype
            if (vdt == object or vdt.kind not in "fiu"
                    or not fallback.device_supports_dtype(vdt)):
                return None
            stats = want.setdefault(value, set())
            if fn in ("sum", "mean"):
                stats.add("sum")
            elif fn in ("min", "max"):
                stats.add(fn)
        kok = payload_validity(kv, kvalid)
        any_null = not bool(kok.all())
        if kok.any():
            lo = int(kv[kok].min())
            span = int(kv[kok].max()) - lo + 1
        else:
            lo, span = 0, 0
        if span > MAX_TABLE_SPAN or not dense_span_affordable(span, n):
            return None
        n_slots = span + (1 if any_null else 0)   # last slot = NULL group
        seg_shard = _next_pow2(-(-n_slots // ndev))
        if ndev * seg_shard > MAX_TABLE_SPAN:
            return None
        nseg = ndev * seg_shard

        # host: O(n) rebase to dense slot codes — no sort, no factorize
        def rebase(v):
            if v.dtype.kind == "u" and v.dtype.itemsize == 8:
                return (v - v.dtype.type(lo)).astype(np.int32)
            return (v.astype(np.int64) - lo).astype(np.int32)

        gid = rebase(kv)
        if any_null:
            gid[~kok] = np.int32(span)
        chunk = -(-n // ndev)
        pad = ndev * chunk - n

        def slab(arr, fill):
            if pad:
                arr = np.concatenate(
                    [arr, np.full(pad, fill, dtype=arr.dtype)])
            return arr.reshape(ndev, chunk)

        # first-appearance per slot stays on the host: the rebase
        # already materialized gid, so a reversed fancy assignment
        # (later writes win, so the reversed order leaves each slot
        # holding its FIRST row) beats shipping a row-id slab and a
        # whole extra segment reduce through the exchange.
        first = np.full(n_slots, n, dtype=np.int64)
        first[gid[::-1]] = np.arange(n - 1, -1, -1, dtype=np.int64)

        gid_slab = slab(gid, np.int32(0))    # padding: slot 0, ok=False
        col_sig = []
        col_slabs = []
        col_names = list(want)
        for name in col_names:
            values, valid = cols[name]
            ok = payload_validity(values, valid)
            col_sig.append((values.dtype.str,
                            tuple(sorted(want[name]))))
            col_slabs.append(slab(values, fill_value(values.dtype)))
            col_slabs.append(slab(ok, False))

        fn = _partial_agg_fn(ndev, seg_shard, tuple(col_sig),
                             self.use_pallas, self.interpret)
        rec = get_recorder()
        kernel_ctx = _NOOP_CTX
        if rec.enabled:
            # the exchange ships one lane per (shard, key slot) per
            # partial vector — reduced slabs, never input rows: per
            # column one COUNT partial (int32) plus one value-dtype
            # partial per requested stat, each ndev*nseg lanes.
            lanes = ndev * ndev * seg_shard
            bytes_moved = sum(
                lanes * (4 + np.dtype(dt).itemsize * len(stats))
                for dt, stats in col_sig)
            kernel_ctx = rec.span(
                "kernel", op="sharded.partial_agg", ndev=ndev,
                rows=n, slots=n_slots, all_to_all_bytes=bytes_moved)
            rec.metrics.histogram(
                "sharded.all_to_all_bytes").observe(bytes_moved)
        # the packed strategy sorts int64-packed lanes; the x64 scope
        # is thread-local and only governs types traced inside.
        with kernel_ctx:
            with jax.experimental.enable_x64():
                outs = [np.asarray(o).reshape(-1) for o in
                        fn(gid_slab, *col_slabs)]

        # unpack in the body's emission order
        stats_of: dict[str, dict[str, np.ndarray]] = {}
        i = 0
        for name, (_dt, stats) in zip(col_names, col_sig):
            got = {"count": outs[i]}
            i += 1
            for s in ("sum", "min", "max"):
                if s in stats:
                    got[s] = outs[i]
                    i += 1
            stats_of[name] = got

        # host finalize: presence + first-appearance order from ONE
        # small argsort over distinct keys (never over rows)
        codes = np.flatnonzero(first < n)
        out_codes = codes[np.argsort(first[codes], kind="stable")]
        kdt = kv.dtype
        if kdt.kind == "u" and kdt.itemsize == 8:
            keyvals = kdt.type(lo) + out_codes.astype(kdt)
        else:
            keyvals = (out_codes + lo).astype(kdt)
        kmask = np.ones(len(out_codes), dtype=bool)
        if any_null:
            kmask = out_codes != span
            keyvals[~kmask] = fill_value(kdt)
        data: dict[str, tuple[np.ndarray, np.ndarray | None]] = {
            keys[0]: (keyvals, kmask)}
        for fname, value, out_name in specs:
            got = stats_of[value]
            cnt = got["count"][out_codes].astype(np.int64)
            if fname == "count":
                data[out_name] = (cnt, None)
                continue
            has = cnt > 0
            vdt = cols[value][0].dtype
            if fname == "sum":
                s = got["sum"][out_codes].astype(vdt, copy=True)
                s[~has] = fill_value(vdt)
                data[out_name] = (s, has)
            elif fname == "mean":
                m = got["sum"][out_codes].astype(np.float64)
                np.divide(m, cnt, out=m, where=has)
                m[~has] = fill_value(np.dtype(np.float64))
                data[out_name] = (m, has)
            else:
                r = got[fname][out_codes].astype(vdt, copy=True)
                r[~has] = fill_value(vdt)
                data[out_name] = (r, has)
        return data


def _buckets(keys: np.ndarray, ndev: int, span_shard: int
             ) -> np.ndarray:
    """Owner shard per row, uint8; >= ndev for unmatchable rows (they
    sort to the tail of every chunk and are never placed).

    Range mode is a single shift: span_shard is a power of two no
    wider than MAX_TABLE_SPAN/ndev, so the int32 sentinel (all ones
    below bit 31) shifts to >= 255 — no separate sentinel pass."""
    if span_shard > 0:
        sh = span_shard.bit_length() - 1
        # valid codes shift below ndev; the sentinel shifts to at least
        # 16*ndev, so clipping to ndev (the drop bucket) is exact.
        return np.minimum(keys >> sh, ndev).astype(np.uint8)
    sent = keys.dtype.type(np.iinfo(keys.dtype).max)
    if keys.dtype.itemsize > 4:
        folded = ((keys >> 32) ^ keys).astype(np.int32)
    else:
        folded = keys.astype(np.int32)
    b = _mix32(folded).astype(np.int64) % ndev
    return np.where(keys != sent, b, ndev).astype(np.uint8)


def _partition(keys: np.ndarray, buckets: np.ndarray, ndev: int
               ) -> tuple[np.ndarray, np.ndarray, int]:
    """Host radix partition into (src, owner, cap) slabs.

    One byte-radix (counting) argsort per source chunk — numpy's
    stable integer argsort is a radix sort, so the host path never
    pays a comparison sort. Returns (key slabs, original-row-index
    slabs (-1 padding), cap). Stable per (src, owner) pair — rows keep
    original order, which the device-side arrival order inherits.
    """
    n = len(keys)
    chunk = -(-n // ndev)
    counts = np.bincount(
        (np.arange(n, dtype=np.int64) // chunk) * (ndev + 1) + buckets,
        minlength=ndev * (ndev + 1)).reshape(ndev, ndev + 1)
    cap = _round_cap(int(counts[:, :ndev].max()))
    sent = keys.dtype.type(np.iinfo(keys.dtype).max)
    slab = np.full((ndev, ndev, cap), sent, dtype=keys.dtype)
    idx = np.full((ndev, ndev, cap), -1, dtype=np.int32)
    for s in range(ndev):
        lo = s * chunk
        hi = min(n, lo + chunk)
        if lo >= hi:
            continue
        order = np.argsort(buckets[lo:hi], kind="stable")
        ks = keys[lo:hi][order]
        rows = (order + lo).astype(np.int32)
        off = 0
        for d in range(ndev):
            c = int(counts[s, d])
            slab[s, d, :c] = ks[off:off + c]
            idx[s, d, :c] = rows[off:off + c]
            off += c
    return slab, idx, cap
