"""Shard-aware distributed hash join across the JAX device mesh.

Extends the ``jax`` backend (which already runs aggregation through
``kernels/segment_sum``) with a mesh-parallel ``hash_join``: the join
inner loop — the dominant cost of every pipeline wave — is partitioned
over a 1-D ``("shard",)`` mesh so each device owns one key range and
probes only its cache-resident slice, instead of the vectorized
backend's whole-table binary search whose every step misses cache at
1e6+ rows. DESIGN.md §10.

Division of labor (host steps are numpy, device steps run under
``shard_map``):

1. **Key coding** (host). Single same-kind integer keys are rebased to
   ``key - min`` and ship raw when the span fits int32 — no
   factorization at all, the sharded twin of the vectorized backend's
   direct-address fast path, except the key space is *distributed*:
   each shard owns ``span/ndev`` of it, so the trick keeps working at
   spans where the single-host bincount heuristic gives up. Everything
   else (multi-column, object, cross-kind, wide-span keys) goes
   through the existing joint factorization
   (``vectorized._join_codes``) to dense codes — the factorization IS
   the hash, so the per-shard slot space is perfect (collision-free).
   64-bit keys that cannot lower because ``jax_enable_x64`` is off
   degrade to the vectorized backend through the shared
   ``kernels.fallback`` plumbing — loudly, not silently. Unmatchable
   rows (NULL / NaN keys) are coded to the dtype-max sentinel.
2. **Radix partition** (host). Rows are counting-sorted (a per-chunk
   byte radix pass — no comparison sort anywhere on the host path)
   into ``(src_device, owner_shard, capacity)`` slabs — owner =
   contiguous key range, or a mixing hash for wide-span raw keys.
   Capacity is exact (one bincount), so the exchange can never
   overflow; shapes round to powers of two so the jit cache stays
   small. The host keeps the permutation, so devices exchange *keys
   only* and results map back with pure index arithmetic.
3. **all_to_all + per-shard probe** (device). A tiled ``all_to_all``
   turns the src-major slabs into owner-major rows (arrival order ==
   global row order — this is what preserves the reference's
   right-occurrence order). Each shard sorts its build keys (one
   single-operand sort; sentinels sink to the end) and emits per probe
   lane the (start, count) of its match run. Two probe strategies:

   - default: two ``searchsorted`` passes over the shard-local sorted
     run — with build sides deduplicated by construction (the common
     FK shape, detected on device by an adjacent-equal scan) the
     grouped layout is the sorted order itself and per-lane ranks come
     from one more binary search; duplicate build keys take a
     ``lax.cond`` branch that stable-sorts (key, arrival) pairs
     instead.
   - ``REPRO_HASHJOIN_PALLAS=1`` (the TPU compile target): build the
     open-addressing (start, count) direct-address table over the
     shard's slot range and probe it through ``kernels/hash_join`` —
     the Pallas one-hot probe kernel, or its XLA gather oracle under
     ``interpret``-less CPU runs. Mirrors ``kernels/segment_sum``:
     the kernel is the accelerator path, the host default is whatever
     measures fastest there.
4. **Ragged emission** (host). Per-shard (start, count) pairs are
   offset by the shard's stride, scattered back to original left row
   order through the kept permutation, and expanded by the vectorized
   backend's ``_emit_join`` — which is what makes the output
   bit-for-bit identical to ``reference``, row order included.

Aggregation, filter and concat are inherited (segment-sum kernel /
numpy): the ROADMAP item this implements is specifically the
distributed join.
"""
from __future__ import annotations

import functools
import os
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_map
from repro.exec.base import Columns, _column_length, payload_validity
from repro.exec.jax_backend import JaxBackend
from repro.exec.vectorized import _and_key_validity, _join_codes
from repro.kernels import fallback
from repro.kernels.hash_join.ops import hash_probe, masked_hash_probe

__all__ = ["ShardedBackend"]

# Key spans up to this use contiguous-range partitioning with a
# power-of-two per-shard slot space ("table" mode — required for the
# Pallas direct-address path; also keeps the bucket computation a pure
# shift with the dtype-max sentinel safely out of shard range). Wider
# key spaces hash-partition ("hash" mode); anything that fits int32
# still ships as int32.
MAX_TABLE_SPAN = 1 << 26


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def _round_cap(n: int) -> int:
    """Slab capacity rounding: up to the next multiple of the value's
    third-highest bit — at most 12.5% padding (a pure power of two
    wastes up to 2x at awkward sizes), while keeping the set of
    distinct jit shapes small."""
    n = max(int(n), 64)
    gran = max(64, 1 << (n.bit_length() - 3))
    return -(-n // gran) * gran


def _mix32(h: np.ndarray) -> np.ndarray:
    """Deterministic int32 mixing hash (wraparound multiply)."""
    h = h ^ (h >> np.int32(16))
    with np.errstate(over="ignore"):
        h = (h * np.int32(0x45D9F3B)).astype(np.int32)
    h = h ^ (h >> np.int32(13))
    return h & np.int32(0x7FFFFFFF)


@functools.lru_cache(maxsize=None)
def _get_mesh(ndev: int):
    return jax.make_mesh((ndev,), ("shard",),
                         devices=jax.devices()[:ndev])


@functools.lru_cache(maxsize=64)
def _probe_fn(ndev: int, cap_l: int, cap_r: int, span_shard: int,
              np_dtype: str, use_pallas: bool, interpret: bool,
              masked: bool = False):
    """Build + jit the shard_map'd exchange-and-probe for one static
    signature. Unmatchable lanes (NULL/NaN keys and slab padding)
    carry the dtype-max sentinel and can match nothing: they sort to
    the end, fall outside every table slot, and are masked out of
    counts. ``span_shard`` > 0 selects the direct-address slot space
    of "table" mode (required for the Pallas path); 0 means wide-span
    raw keys. ``masked`` adds a probe-side keep-mask slab and routes
    through the filter-fused Pallas probe (table mode only — the
    caller host-poisons keys to the sentinel on every other route)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = _get_mesh(ndev)
    dtype = np.dtype(np_dtype)
    sent = dtype.type(np.iinfo(dtype).max)

    def exchange(slab):                  # (1, ndev, cap) -> (ndev*cap,)
        x = jax.lax.all_to_all(slab[0], "shard", split_axis=0,
                               concat_axis=0, tiled=True)
        # src-major flatten: arrival order == global row order, which
        # is what lets the grouped layouts below reproduce the
        # reference's right-occurrence order within a key.
        return x.reshape(-1)

    def probe_packed(lk, rk):
        """Packed-sort strategy for int32 keys (the CPU-mesh default).

        One single-operand sort of ``key << 32 | arrival`` orders the
        build side by key with ties in arrival — i.e. global row —
        order, so the grouped layout AND its arrival translation
        (``gidx``) fall out of the same sort with no stable pair sort,
        no scatter, and no separate duplicate-key path. Sentinel lanes
        (padding / NULL keys) pack highest and sink to the tail. The
        probe is one binary search; the count is a hit-check gather
        when the build keys are unique (the common FK shape) and a
        second binary search otherwise."""
        m = rk.shape[0]
        iota = jnp.arange(m, dtype=jnp.int64)
        packed = (rk.astype(jnp.int64) << 32) | iota
        p_srt = jax.lax.sort(packed)
        k_srt = (p_srt >> 32).astype(jnp.int32)
        gidx = (p_srt & jnp.int64(0xFFFFFFFF)).astype(jnp.int32)
        starts = jnp.searchsorted(k_srt, lk).astype(jnp.int32)
        dup = jnp.any((k_srt[1:] == k_srt[:-1]) & (k_srt[1:] != sent))

        def fast(_):
            hit = (k_srt[jnp.minimum(starts, m - 1)] == lk) \
                & (lk != sent)
            return hit.astype(jnp.int32)

        def slow(_):
            ends = jnp.searchsorted(k_srt, lk, side="right")
            return jnp.where(lk != sent,
                             ends - starts.astype(ends.dtype),
                             0).astype(jnp.int32)

        counts = jax.lax.cond(dup, slow, fast, None)
        return starts, counts, gidx

    def probe_wide(lk, rk):
        """int64 keys (jax_enable_x64 verified upstream): stable
        (key, arrival) pair sort + two binary searches."""
        m = rk.shape[0]
        iota = jnp.arange(m, dtype=jnp.int32)
        k_srt, gidx = jax.lax.sort((rk, iota), num_keys=1,
                                   is_stable=True)
        starts = jnp.searchsorted(k_srt, lk, side="left")
        ends = jnp.searchsorted(k_srt, lk, side="right")
        counts = jnp.where(lk != sent, ends - starts, 0)
        return (starts.astype(jnp.int32), counts.astype(jnp.int32),
                gidx)

    def probe_table(lk, rk, lmask=None):
        """Direct-address strategy (the Pallas/TPU path): build the
        open-addressing (start, count) table over this shard's slot
        range, probe through kernels/hash_join. Grouped layout is
        arrival order (unique) or sorted order (duplicates).
        ``lmask`` (filter-fused probe) zeroes masked lanes inside the
        kernel — the filtered rows never leave VMEM."""
        m = rk.shape[0]
        iota = jnp.arange(m, dtype=jnp.int32)
        base = (jax.lax.axis_index("shard") * span_shard).astype(
            jnp.int32)
        slot_r = rk - base               # sentinel -> far out of range
        slot_l = lk - base
        counts_tab = jnp.zeros(span_shard, jnp.int32).at[slot_r].add(
            1, mode="drop")
        unique = jnp.max(counts_tab, initial=0) <= 1

        def fast(_):
            # unique build keys: the grouped layout IS arrival order;
            # start[slot] = the one arrival position.
            pos_tab = jnp.full(span_shard, -1, jnp.int32).at[
                slot_r].set(iota, mode="drop")
            return pos_tab, iota

        def slow(_):
            # duplicate keys: stable-sort the shard by slot (ties keep
            # arrival == global row order) and scatter-min run starts.
            srt, gidx = jax.lax.sort(
                (jnp.where(rk != sent, slot_r, span_shard), iota),
                num_keys=1, is_stable=True)
            pos_tab = jnp.full(span_shard, m, jnp.int32).at[srt].min(
                jnp.arange(m, dtype=jnp.int32), mode="drop")
            return pos_tab, gidx

        pos_tab, gidx = jax.lax.cond(unique, fast, slow, None)
        if lmask is None:
            starts, counts = hash_probe(pos_tab, counts_tab, slot_l,
                                        use_pallas=use_pallas,
                                        interpret=interpret)
        else:
            starts, counts = masked_hash_probe(
                pos_tab, counts_tab, slot_l, lmask,
                use_pallas=use_pallas, interpret=interpret)
        return starts, counts, gidx

    def body_masked(l_slab, m_slab, r_slab):
        # fused-filter path: selected only for table mode + Pallas, so
        # the probe is always the direct-address kernel with the mask
        # slab riding next to the key slab (same owner-major layout).
        lk = l_slab[0].reshape(-1)
        lmask = m_slab[0].reshape(-1)
        rk = exchange(r_slab)
        starts, counts, gidx = probe_table(lk, rk, lmask)
        return starts[None, :], counts[None, :], gidx[None, :]

    def body(l_slab, r_slab):
        # build side: all_to_all so each device owns every row of its
        # key range. Probe side: the host already laid slabs out
        # owner-major (same src-major arrival order the exchange would
        # produce), so probes just flatten — one collective, not two.
        lk = l_slab[0].reshape(-1)
        rk = exchange(r_slab)
        if use_pallas and span_shard:
            probe = probe_table
        elif dtype.itemsize > 4:
            probe = probe_wide
        else:
            probe = probe_packed
        starts, counts, gidx = probe(lk, rk)
        return starts[None, :], counts[None, :], gidx[None, :]

    spec = P("shard", None, None)
    out = P("shard", None)
    fn = body_masked if masked else body
    in_specs = (spec,) * (3 if masked else 2)
    mapped = shard_map(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=(out, out, out), check_vma=False)
    shard = NamedSharding(mesh, spec)
    return jax.jit(mapped, in_shardings=(shard,) * len(in_specs))


class ShardedBackend(JaxBackend):
    name = "sharded"

    def __init__(self, *, n_devices: int | None = None,
                 use_pallas: bool | None = None,
                 use_pallas_probe: bool | None = None,
                 interpret: bool | None = None):
        super().__init__(use_pallas=use_pallas, interpret=interpret)
        if use_pallas_probe is None:
            use_pallas_probe = os.environ.get(
                "REPRO_HASHJOIN_PALLAS") == "1"
        self.use_pallas_probe = use_pallas_probe
        self.n_devices = (n_devices if n_devices is not None
                          else len(jax.devices()))

    # cache-key interaction (DESIGN.md §10): a mesh change regroups row
    # placement (and, through the inherited device aggregation, float
    # SUM summation order under the documented carve-out), so the shard
    # count must move every engine cache key — and so must the
    # inherited segment-sum Pallas flag, whose tiling regroups float
    # sums too. The probe strategy flag is deliberately absent: probe
    # outputs are integer-exact identical across strategies.
    def cache_token(self) -> str:
        suffix = "+pallas" if self.use_pallas else ""
        return f"{self.name}{suffix}[devices={self.n_devices}]"

    # -- join -----------------------------------------------------------
    def hash_join(self, left: Columns, right: Columns,
                  on: Sequence[str], how: str = "inner") -> Columns:
        return self._sharded_join(left, right, on, how, None)

    def masked_hash_join(self, left: Columns, right: Columns,
                         on: Sequence[str], how: str = "inner", *,
                         left_mask: "np.ndarray | None" = None,
                         right_mask: "np.ndarray | None" = None
                         ) -> Columns:
        """Filter-fused distributed join. The right mask folds into the
        key validity on the host before coding (masked build rows code
        to the sentinel and land in the drop bucket — they never ship).
        The left (probe) mask rides to the device as a slab and is
        applied *inside* the Pallas probe kernel when table mode is
        active — the filtered rows never leave VMEM; every other route
        host-poisons the coded keys to the sentinel, which the existing
        sentinel machinery drops for free. ``how='left'`` with a left
        mask must prefilter (a masked row must not emit as unmatched).
        """
        if left_mask is not None and how != "inner":
            left = self.filter_select(left, left_mask)
            left_mask = None
        if right_mask is not None:
            right = _and_key_validity(right, on, right_mask)
        return self._sharded_join(left, right, on, how, left_mask)

    def _host_fallback(self, left: Columns, right: Columns,
                       on: Sequence[str], how: str,
                       probe_mask: "np.ndarray | None") -> Columns:
        if probe_mask is None:
            return super().hash_join(left, right, on, how)
        return super().masked_hash_join(left, right, on, how,
                                        left_mask=probe_mask)

    def _sharded_join(self, left: Columns, right: Columns,
                      on: Sequence[str], how: str,
                      probe_mask: "np.ndarray | None") -> Columns:
        n_left = _column_length(left)
        n_right = _column_length(right)
        ndev = max(1, self.n_devices)
        if (n_left == 0 or n_right == 0
                or n_left >= 2**31 or n_right >= 2**31
                or ndev > 255):          # buckets are uint8
            return self._host_fallback(left, right, on, how, probe_mask)

        keyed = self._device_keys(left, right, on)
        if keyed is None:               # cannot lower: vectorized path
            return self._host_fallback(left, right, on, how, probe_mask)
        lk, rk, span = keyed
        if span == 0:                   # no valid key anywhere
            if probe_mask is not None and how != "inner":
                left = self.filter_select(left, probe_mask)
                n_left = _column_length(left)
            return self._emit_join(
                left, right, how, n_left,
                np.zeros(n_left, np.int64), np.zeros(n_left, np.int64),
                np.array([], dtype=np.int64))
        # power-of-two per-shard slot space: buckets become a shift and
        # the dtype-max sentinel lands safely past the last shard.
        span_shard = (_next_pow2(-(-span // ndev))
                      if 0 < span <= MAX_TABLE_SPAN else 0)

        # fused-filter dispatch: table mode + Pallas keeps the mask on
        # the device (in-VMEM); every other route poisons masked lanes
        # to the sentinel here — they bucket to the drop lane and never
        # even ship.
        fused = (probe_mask is not None and self.use_pallas_probe
                 and span_shard > 0)
        if probe_mask is not None and not fused:
            sent = lk.dtype.type(np.iinfo(lk.dtype).max)
            lk = np.where(np.asarray(probe_mask, dtype=bool), lk, sent)

        lb = _buckets(lk, ndev, span_shard)
        rb = _buckets(rk, ndev, span_shard)
        l_slab, l_idx, cap_l = _partition(lk, lb, ndev)
        r_slab, r_idx, cap_r = _partition(rk, rb, ndev)
        if ndev * cap_l >= 2**31 or ndev * cap_r >= 2**31:
            # padded per-shard lane counts must fit the int32 arrival
            # positions the probes pack — possible past ~2e9 rows with
            # heavy bucket skew even though the raw row counts passed
            # the guard above.
            return self._host_fallback(left, right, on, how, probe_mask)
        # probe side ships owner-major (src stays the minor axis, so
        # per-device arrival order matches what the build side's
        # all_to_all produces).
        l_slab = np.ascontiguousarray(l_slab.transpose(1, 0, 2))

        fn = _probe_fn(ndev, cap_l, cap_r, span_shard, lk.dtype.str,
                       self.use_pallas_probe, self.interpret,
                       masked=fused)
        if fused:
            keep = np.asarray(probe_mask, dtype=bool)
            m_slab = np.where(
                l_idx >= 0, keep[np.clip(l_idx, 0, None)], False
            ).astype(np.int32)
            m_slab = np.ascontiguousarray(m_slab.transpose(1, 0, 2))
            args = (l_slab, m_slab, r_slab)
        else:
            args = (l_slab, r_slab)
        # the packed/wide probes carry int64 intermediates; the x64
        # scope is thread-local and only governs types traced inside.
        with jax.experimental.enable_x64():
            out = fn(*args)
        starts, counts, gidx = (np.asarray(o) for o in out)

        # map device results back through the kept permutation: the
        # grouped layout is the per-shard arrival order permuted by
        # gidx, and arrival order is the host's own slab layout — so
        # the translation to global row ids is one gather, and padding
        # arrival cells (-1) become holes the emission never reads.
        # Per-key runs are contiguous on exactly one shard, so
        # concatenating shard layouts (stride = ndev*cap_r) is a valid
        # grouped layout for the shared ragged emission.
        stride = ndev * cap_r
        arr_l = l_idx.transpose(1, 0, 2).reshape(ndev, ndev * cap_l)
        arr_r = r_idx.transpose(1, 0, 2).reshape(ndev, stride)
        ridx = np.take_along_axis(
            arr_r, gidx.astype(np.int64, copy=False), axis=1
        ).reshape(-1)
        # int64 accumulators: the ragged emission cumsums counts, and
        # a >2**31-row join output must not wrap there.
        starts_g = np.zeros(n_left, np.int64)
        counts_g = np.zeros(n_left, np.int64)
        m = arr_l >= 0
        starts_g[arr_l[m]] = (starts.astype(np.int64)
                              + (np.arange(ndev, dtype=np.int64)
                                 * stride)[:, None])[m]
        counts_g[arr_l[m]] = counts[m]
        return self._emit_join(left, right, how, n_left, starts_g,
                               counts_g,
                               ridx.astype(np.int64, copy=False))

    # -- key coding ------------------------------------------------------
    def _device_keys(self, left: Columns, right: Columns,
                     on: Sequence[str]):
        """(lkeys, rkeys, span) with unmatchable rows already coded to
        the dtype-max sentinel; span > 0 = int32 slot codes ("table"
        mode), span < 0 = raw keys, hash partition ("hash" mode);
        span == 0 = no valid keys at all. None when the keys cannot
        lower to the device without losing bits (the shared
        numpy-fallback plumbing warns)."""
        raw = self._raw_int_keys(left, right, on)
        if raw is not None:
            return raw
        lcodes, rcodes = _join_codes(left, right, on)
        card = int(max(lcodes.max(initial=-1),
                       rcodes.max(initial=-1))) + 1
        if card == 0:
            return lcodes.astype(np.int32), rcodes.astype(np.int32), 0
        if card >= 2**31 - 64:
            # row counts are int32-checked upstream, so a cardinality
            # past the int32 code space is unreachable in practice —
            # keep the guard anyway (codes must fit int32 + sentinel).
            fallback.warn_numpy_fallback(
                "sharded.hash_join", np.dtype(np.int64),
                reason="joint key cardinality exceeds the int32 code "
                       "space")
            return None
        sent = np.int32(np.iinfo(np.int32).max)
        lk = lcodes.astype(np.int32)
        rk = rcodes.astype(np.int32)
        lk[lk < 0] = sent
        rk[rk < 0] = sent
        return lk, rk, card

    def _raw_int_keys(self, left: Columns, right: Columns,
                      on: Sequence[str]):
        """Single same-kind integer key: ship rebased raw values (numpy
        equality == Python equality for int kinds), skipping
        factorization — the sharded twin of the vectorized
        direct-address fast path, distributed so it scales past the
        single-host span budget."""
        if len(on) != 1:
            return None
        lv, lval = left[on[0]]
        rv, rval = right[on[0]]
        if (lv.dtype == object or rv.dtype == object
                or lv.dtype.kind not in "iu"
                or lv.dtype.kind != rv.dtype.kind):
            return None
        lok = payload_validity(lv, lval)
        rok = payload_validity(rv, rval)
        if not lok.any() or not rok.any():
            return None                   # codes path handles trivially
        lo = min(int(lv[lok].min()), int(rv[rok].min()))
        hi = max(int(lv[lok].max()), int(rv[rok].max()))
        span = hi - lo + 1
        sent32 = np.int32(np.iinfo(np.int32).max)
        if (0 <= lo and hi < 2**31 - 64
                and (hi < MAX_TABLE_SPAN or span > MAX_TABLE_SPAN)):
            # values are already valid int32 slot codes — no rebase
            # pass; span = hi+1 keeps shard 0 a touch wider, which the
            # exact capacity computation absorbs. NOT taken when only
            # the rebased span fits the table budget (dense-but-offset
            # keys): the shortcut must never cost table mode — and
            # with it the Pallas probe path — that the rebase below
            # would keep.
            lk = lv.astype(np.int32)
            rk = rv.astype(np.int32)
            lk[~lok] = sent32
            rk[~rok] = sent32
            return lk, rk, hi + 1
        if span <= 2**31 - 64:
            # rebase to slot codes: the distributed key space absorbs
            # the sparsity (span/ndev slots per shard). Two exact
            # routes: uint64 subtracts in its native dtype (lo is the
            # joint min, so no wrap — an int64 intermediate would
            # overflow past 2**63); every other kind widens to int64
            # first (native-width subtraction would wrap int8/int16
            # spans, and lo — the min across BOTH sides, possibly a
            # wider dtype — need not fit the narrow dtype at all).
            # Either way the rebased values are < span < 2**31.
            def rebase(v):
                if v.dtype.kind == "u" and v.dtype.itemsize == 8:
                    return (v - v.dtype.type(lo)).astype(np.int32)
                return (v.astype(np.int64) - lo).astype(np.int32)

            lk = rebase(lv)
            rk = rebase(rv)
            lk[~lok] = sent32
            rk[~rok] = sent32
            return lk, rk, span
        if -2**63 <= lo and hi <= 2**63 - 2:
            if not fallback.device_supports_dtype(np.dtype(np.int64)):
                # NOT a whole-op fallback: the join still runs on the
                # mesh through factorized int32 codes — what degrades
                # is the key coding (a host np.unique pass replaces
                # shipping raw int64 keys). Warn with the accurate
                # scope, still naming the env fix.
                fallback.warn_numpy_fallback(
                    "sharded.hash_join", lv.dtype,
                    reason="wide-span 64-bit keys take the host "
                           "factorization path; enable jax_enable_x64 "
                           "(e.g. JAX_ENABLE_X64=1) to ship raw int64 "
                           "keys to the device")
                return None               # codes path (still sharded)
            sent = np.int64(np.iinfo(np.int64).max)
            lk = lv.astype(np.int64)
            rk = rv.astype(np.int64)
            lk[~lok] = sent
            rk[~rok] = sent
            return lk, rk, -1
        return None                       # uint64 tail: codes path


def _buckets(keys: np.ndarray, ndev: int, span_shard: int
             ) -> np.ndarray:
    """Owner shard per row, uint8; >= ndev for unmatchable rows (they
    sort to the tail of every chunk and are never placed).

    Range mode is a single shift: span_shard is a power of two no
    wider than MAX_TABLE_SPAN/ndev, so the int32 sentinel (all ones
    below bit 31) shifts to >= 255 — no separate sentinel pass."""
    if span_shard > 0:
        sh = span_shard.bit_length() - 1
        # valid codes shift below ndev; the sentinel shifts to at least
        # 16*ndev, so clipping to ndev (the drop bucket) is exact.
        return np.minimum(keys >> sh, ndev).astype(np.uint8)
    sent = keys.dtype.type(np.iinfo(keys.dtype).max)
    if keys.dtype.itemsize > 4:
        folded = ((keys >> 32) ^ keys).astype(np.int32)
    else:
        folded = keys.astype(np.int32)
    b = _mix32(folded).astype(np.int64) % ndev
    return np.where(keys != sent, b, ndev).astype(np.uint8)


def _partition(keys: np.ndarray, buckets: np.ndarray, ndev: int
               ) -> tuple[np.ndarray, np.ndarray, int]:
    """Host radix partition into (src, owner, cap) slabs.

    One byte-radix (counting) argsort per source chunk — numpy's
    stable integer argsort is a radix sort, so the host path never
    pays a comparison sort. Returns (key slabs, original-row-index
    slabs (-1 padding), cap). Stable per (src, owner) pair — rows keep
    original order, which the device-side arrival order inherits.
    """
    n = len(keys)
    chunk = -(-n // ndev)
    counts = np.bincount(
        (np.arange(n, dtype=np.int64) // chunk) * (ndev + 1) + buckets,
        minlength=ndev * (ndev + 1)).reshape(ndev, ndev + 1)
    cap = _round_cap(int(counts[:, :ndev].max()))
    sent = keys.dtype.type(np.iinfo(keys.dtype).max)
    slab = np.full((ndev, ndev, cap), sent, dtype=keys.dtype)
    idx = np.full((ndev, ndev, cap), -1, dtype=np.int32)
    for s in range(ndev):
        lo = s * chunk
        hi = min(n, lo + chunk)
        if lo >= hi:
            continue
        order = np.argsort(buckets[lo:hi], kind="stable")
        ks = keys[lo:hi][order]
        rows = (order + lo).astype(np.int32)
        off = 0
        for d in range(ndev):
            c = int(counts[s, d])
            slab[s, d, :c] = ks[off:off + c]
            idx[s, d, :c] = rows[off:off + c]
            off += c
    return slab, idx, cap
