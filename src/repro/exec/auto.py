"""Statistics-driven backend auto-selection (the ``auto`` policy).

``auto`` is a registered backend that never executes an operator
itself: each call collects :mod:`~repro.exec.stats` for its inputs and
delegates to the registered backend the decision table picks. The
table is deliberately small and fully unit-tested
(``tests/test_sharded_join.py``):

====================  ==========================================  =========
operation             condition (first match wins)                backend
====================  ==========================================  =========
join / group_by_agg   total rows <= tiny (64)                     reference
join                  single int key, span <= 4*(nl+nr)+1024      vectorized
join                  rows >= shard_rows AND >1 device            sharded
join                  anything else                               vectorized
group_by_agg          rows >= shard_rows AND >1 device AND        sharded
                      single dense int key AND dtypes lower
group_by_agg          rows >= device_rows AND dtypes lower        jax
group_by_agg          anything else                               vectorized
====================  ==========================================  =========

Rationale per row: tiny tables are dominated by per-call constants,
where the interpreted reference's plain dicts beat any array setup;
dense single-int-key joins hit the vectorized backend's direct-address
bincount probe, which no device round-trip amortizes; large joins are
the one place the mesh pays (the sharded radix exchange); large
aggregations with a dense single integer key take the sharded
backend's pre-exchange partial aggregation when the mesh has more than
one device (the exchange ships one lane per (shard, distinct key), so
high-duplication keys collapse before any cross-device traffic),
otherwise they lower to the segment-reduce kernel family when every
value dtype can live on the device. A picked backend that turns out
unavailable on this install (no JAX) degrades one row down, never
errors.

Thresholds are tunable by env (``REPRO_AUTO_TINY_ROWS``,
``REPRO_AUTO_SHARD_ROWS``, ``REPRO_AUTO_DEVICE_ROWS``) because they
are machine constants, not semantics: every candidate agrees with
``reference`` bit for bit, so a wrong pick costs time, never
correctness. The engine folds :meth:`AutoBackend.cache_token` — policy
version, thresholds, and device count — into node cache keys, so a
policy or mesh change can never serve a stale cross-backend cache hit.
"""
from __future__ import annotations

import os
from typing import Sequence

import numpy as np

from repro.exec.base import AggSpec, Backend, Columns, normalize_agg_specs
from repro.exec.stats import TableStats, collect_stats
from repro.obs import get_recorder

__all__ = ["AutoBackend", "choose_join", "choose_group_by",
           "choose_group_by_agg", "explain_join", "explain_group_by_agg"]

# v2: group-by policy learned the sharded partial-aggregation row (and
# group_by_sum now routes through it) — the bump moves every auto cache
# key so pre-partial-agg entries cannot be served to the new policy.
_POLICY_VERSION = 2

TINY_ROWS = int(os.environ.get("REPRO_AUTO_TINY_ROWS", "64"))
SHARD_ROWS = int(os.environ.get("REPRO_AUTO_SHARD_ROWS", "200000"))
DEVICE_ROWS = int(os.environ.get("REPRO_AUTO_DEVICE_ROWS", "100000"))


def _dense_span(left: TableStats, right: TableStats) -> bool:
    """The vectorized backend's own direct-address affordability
    predicate over the JOINT key span (from the stats' key bounds —
    per-side spans alone underestimate without bound when the two
    sides' key ranges are disjoint, e.g. ids vs ids + 1e9, and would
    mis-route exactly the cache-missing joins the sharded row
    exists to catch)."""
    from repro.exec.vectorized import dense_span_affordable
    if None in (left.int_key_lo, left.int_key_hi,
                right.int_key_lo, right.int_key_hi):
        return False
    span = (max(left.int_key_hi, right.int_key_hi)
            - min(left.int_key_lo, right.int_key_lo) + 1)
    return dense_span_affordable(span, left.n_rows + right.n_rows)


def explain_join(left: TableStats, right: TableStats, *,
                 n_devices: int = 1,
                 sharded_available: bool = False) -> tuple[str, str]:
    """The join decision table, returning ``(backend, why)`` — the
    reason string names the decision-table row that fired, and rides
    into run manifests as the ``auto_decision`` event's ``reason``."""
    total = left.n_rows + right.n_rows
    if total <= TINY_ROWS:
        return "reference", (
            f"total rows {total} <= tiny threshold {TINY_ROWS}")
    if (left.single_int_key and right.single_int_key
            and _dense_span(left, right)):
        return "vectorized", (
            "single int key with affordable dense span "
            "(direct-address bincount probe)")
    if total >= SHARD_ROWS and n_devices > 1 and sharded_available:
        return "sharded", (
            f"total rows {total} >= shard threshold {SHARD_ROWS} "
            f"on {n_devices} devices")
    return "vectorized", "default row (no specialized row matched)"


def choose_join(left: TableStats, right: TableStats, *,
                n_devices: int = 1,
                sharded_available: bool = False) -> str:
    """The stats -> backend decision table for joins (pure function —
    the unit under test)."""
    return explain_join(left, right, n_devices=n_devices,
                        sharded_available=sharded_available)[0]


def choose_group_by(stats: TableStats, value_dtype: np.dtype, *,
                    jax_available: bool = False) -> str:
    """The single-SUM decision table (kept for back-compat callers;
    the general entry point is :func:`choose_group_by_agg`)."""
    return choose_group_by_agg(stats, (value_dtype,),
                               jax_available=jax_available)


def explain_group_by_agg(stats: TableStats,
                         value_dtypes: Sequence[np.dtype], *,
                         n_devices: int = 1,
                         sharded_available: bool = False,
                         jax_available: bool = False) -> tuple[str, str]:
    """The group_by_agg decision table, returning ``(backend, why)``
    (see :func:`explain_join` for the reason-string contract)."""
    if stats.n_rows <= TINY_ROWS:
        return "reference", (
            f"rows {stats.n_rows} <= tiny threshold {TINY_ROWS}")
    lowers = all(_lowers(dt) for dt in value_dtypes)
    if (stats.n_rows >= SHARD_ROWS and n_devices > 1
            and sharded_available and lowers
            and stats.single_int_key and _dense_group_span(stats)):
        return "sharded", (
            f"rows {stats.n_rows} >= shard threshold {SHARD_ROWS} on "
            f"{n_devices} devices with dense single int key and "
            f"device-lowerable values (pre-exchange partial agg)")
    if stats.n_rows >= DEVICE_ROWS and jax_available and lowers:
        return "jax", (
            f"rows {stats.n_rows} >= device threshold {DEVICE_ROWS} "
            f"with device-lowerable values (segment-reduce kernels)")
    if not lowers:
        return "vectorized", (
            "value dtype(s) not device-lowerable")
    return "vectorized", "default row (no specialized row matched)"


def choose_group_by_agg(stats: TableStats,
                        value_dtypes: Sequence[np.dtype], *,
                        n_devices: int = 1,
                        sharded_available: bool = False,
                        jax_available: bool = False) -> str:
    """The stats -> backend decision table for group_by_agg (pure
    function — the unit under test). First match wins: tiny tables ->
    reference; large tables on a real mesh with a dense single integer
    key and device-lowerable values -> sharded partial aggregation;
    large device-lowerable tables -> jax segment kernels; everything
    else -> vectorized."""
    return explain_group_by_agg(
        stats, value_dtypes, n_devices=n_devices,
        sharded_available=sharded_available,
        jax_available=jax_available)[0]


def _dense_group_span(stats: TableStats) -> bool:
    from repro.exec.vectorized import dense_span_affordable
    if None in (stats.int_key_lo, stats.int_key_hi):
        return False
    span = stats.int_key_hi - stats.int_key_lo + 1
    return dense_span_affordable(span, stats.n_rows)


def _lowers(dtype: np.dtype) -> bool:
    from repro.kernels import fallback
    return fallback.device_supports_dtype(dtype)


class AutoBackend(Backend):
    name = "auto"

    def __init__(self):
        self._n_devices: int | None = None

    # -- registry probes (lazy: auto must construct on JAX-less installs)
    def _available(self, name: str) -> bool:
        from repro import exec as exec_backends
        try:
            exec_backends.get_backend(name)
        except (KeyError, exec_backends.BackendUnavailable):
            return False
        return True

    def _devices(self) -> int:
        if self._n_devices is None:
            try:
                import jax
                self._n_devices = len(jax.devices())
            except ImportError:
                self._n_devices = 1
        return self._n_devices

    def _delegate(self, name: str) -> Backend:
        from repro import exec as exec_backends
        if name != "vectorized" and not self._available(name):
            rec = get_recorder()
            if rec.enabled:
                rec.event("degradation", kind="backend_unavailable",
                          wanted=name, used="vectorized")
            name = "vectorized"
        return exec_backends.get_backend(name)

    def cache_token(self) -> str:
        # compose the possible delegates' own tokens: a per-call
        # policy means any state that would move a delegate's key
        # (device count, segment-sum Pallas flag, jax appearing on the
        # install) must move auto's key too — otherwise a regrouped
        # float SUM could be served from a pre-regrouping cache entry.
        delegated = ",".join(
            self._delegate_token(n) for n in ("jax", "sharded"))
        return (f"auto[v{_POLICY_VERSION};tiny={TINY_ROWS};"
                f"shard={SHARD_ROWS};device={DEVICE_ROWS};"
                f"devices={self._devices()};{delegated}]")

    def _delegate_token(self, name: str) -> str:
        from repro import exec as exec_backends
        if not self._available(name):
            return f"{name}=-"
        return exec_backends.get_backend(name).cache_token()

    # -- operators -------------------------------------------------------
    # The engine threads planner-collected TableStats through dispatch
    # (PlanStep.input_stats): when the caller already measured an
    # input, auto must not re-sample it — stats collection is a full
    # column scan, and double collection was a measured dispatch-path
    # regression. ``None`` stats (post-rewrite intermediates the
    # planner never saw, or direct Table-API calls) are collected here,
    # exactly once, against the physical input of THIS call — which is
    # what makes the decision table consume post-rewrite reality
    # rather than pre-rewrite planner estimates.
    accepts_join_stats = True

    def _join_choice(self, left: Columns, right: Columns,
                     on: Sequence[str],
                     left_stats: "TableStats | None",
                     right_stats: "TableStats | None",
                     op: str = "hash_join") -> str:
        # the decision table reads rows/kinds/span only — skip the
        # cardinality sampling pass on the dispatch hot path.
        if left_stats is None:
            left_stats = collect_stats(left, on,
                                       estimate_cardinality=False)
        if right_stats is None:
            right_stats = collect_stats(right, on,
                                        estimate_cardinality=False)
        choice, reason = explain_join(
            left_stats, right_stats,
            n_devices=self._devices(),
            sharded_available=self._available("sharded"))
        rec = get_recorder()
        if rec.enabled:
            rec.event("auto_decision", op=op, choice=choice,
                      reason=reason, left_rows=left_stats.n_rows,
                      right_rows=right_stats.n_rows,
                      n_devices=self._devices())
            rec.metrics.counter(f"auto.{op}.{choice}").inc()
        return choice

    def hash_join(self, left: Columns, right: Columns,
                  on: Sequence[str], how: str = "inner", *,
                  left_stats: "TableStats | None" = None,
                  right_stats: "TableStats | None" = None) -> Columns:
        choice = self._join_choice(left, right, on, left_stats,
                                   right_stats)
        return self._delegate(choice).hash_join(left, right, on, how)

    def masked_hash_join(self, left: Columns, right: Columns,
                         on: Sequence[str], how: str = "inner", *,
                         left_mask: "np.ndarray | None" = None,
                         right_mask: "np.ndarray | None" = None,
                         left_stats: "TableStats | None" = None,
                         right_stats: "TableStats | None" = None
                         ) -> Columns:
        # stats describe the *unfiltered* physical inputs — the same
        # tables the delegate's fused probe will actually touch, so
        # sizing the choice on them is the honest estimate.
        choice = self._join_choice(left, right, on, left_stats,
                                   right_stats, op="masked_hash_join")
        return self._delegate(choice).masked_hash_join(
            left, right, on, how,
            left_mask=left_mask, right_mask=right_mask)

    accepts_group_stats = True

    def group_by_agg(self, cols: Columns, keys: Sequence[str],
                     specs: Sequence[AggSpec], *,
                     stats: "TableStats | None" = None) -> Columns:
        specs = normalize_agg_specs(cols, keys, specs)
        if stats is None:
            stats = collect_stats(cols, keys,
                                  estimate_cardinality=False)
        choice, reason = explain_group_by_agg(
            stats, tuple(cols[value][0].dtype for _fn, value, _o in specs),
            n_devices=self._devices(),
            sharded_available=self._available("sharded"),
            jax_available=self._available("jax"))
        rec = get_recorder()
        if rec.enabled:
            rec.event("auto_decision", op="group_by_agg",
                      choice=choice, reason=reason, rows=stats.n_rows,
                      n_devices=self._devices())
            rec.metrics.counter(f"auto.group_by_agg.{choice}").inc()
        return self._delegate(choice).group_by_agg(cols, keys, specs)

    def group_by_sum(self, cols: Columns, keys: Sequence[str],
                     value: str, out: str, *,
                     stats: "TableStats | None" = None) -> Columns:
        return self.group_by_agg(cols, keys, (("sum", value, out),),
                                 stats=stats)

    # filter_select / concat: the shared default implementations are
    # already a plain gather/concatenate — nothing to select between.
