"""Pluggable columnar execution backends (DESIGN.md §9).

The table layer (:class:`repro.data.tables.Table`) dispatches its
physical operators — ``hash_join``, ``group_by_agg``, ``filter_select``,
``concat`` — through this registry, so *what* a pipeline computes
(contracts, NULL semantics, row order) is fixed while *how* it executes
is swappable:

- ``reference`` — the original interpreted row loops, kept as the
  differential-testing oracle;
- ``vectorized`` — numpy factorize/sort kernels, the default;
- ``jax``       — accelerator segment-sum aggregation (XLA or the
  Pallas kernel), registered only when JAX imports;
- ``sharded``   — mesh-partitioned distributed hash join (radix
  all_to_all exchange + per-shard probe under ``shard_map``, Pallas
  hash-probe kernel available), inheriting the jax aggregation;
  registered only when JAX imports (DESIGN.md §10);
- ``auto``      — statistics-driven per-call selection among the
  above (exec/auto.py's decision table); always constructs, degrades
  to the host backends on JAX-less installs.

Selection, in precedence order:

1. per-call override: ``table.join(other, on=[...], backend="reference")``;
2. process-wide: :func:`set_backend` / the :func:`use_backend` context
   manager (process-global, *not* thread-scoped — the engine's wave
   threads all see it, which is exactly what keeps one run on one
   backend);
3. environment: ``REPRO_EXEC_BACKEND`` at first use;
4. default: ``vectorized``.

Backends are registered as *factories* and instantiated lazily, so
importing this package never imports JAX; an unimportable backend
surfaces as :class:`BackendUnavailable` at selection time and the
``jax`` entry simply drops out of :func:`available_backends` on
JAX-less installs. The engine folds :func:`active_backend`'s name into
every node cache key (``repro.core.engine.cache_key``), so switching
backends can never serve a snapshot computed by a different
implementation.
"""
from __future__ import annotations

import os
import threading
from contextlib import contextmanager
from typing import Callable

from repro.exec.base import Backend, Columns, fill_value, payload_validity

__all__ = [
    "Backend", "Columns", "fill_value", "payload_validity",
    "BackendUnavailable", "register", "get_backend", "available_backends",
    "active_backend", "set_backend", "use_backend", "resolve",
    "DEFAULT_BACKEND",
]

DEFAULT_BACKEND = "vectorized"


class BackendUnavailable(RuntimeError):
    """A registered backend cannot be constructed (missing dependency)."""


_lock = threading.Lock()
_factories: dict[str, Callable[[], Backend]] = {}
_instances: dict[str, Backend] = {}
_active: str | None = None      # resolved lazily (env) on first use


def register(name: str, factory: Callable[[], Backend]) -> None:
    """Register a backend factory. Construction is deferred to first
    :func:`get_backend` so optional dependencies stay optional."""
    _factories[name] = factory


def get_backend(name: str) -> Backend:
    with _lock:
        be = _instances.get(name)
        if be is not None:
            return be
        factory = _factories.get(name)
        if factory is None:
            raise KeyError(
                f"unknown execution backend {name!r}; registered: "
                f"{sorted(_factories)}")
        try:
            be = factory()
        except ImportError as e:
            raise BackendUnavailable(
                f"execution backend {name!r} is unavailable: {e}") from e
        _instances[name] = be
        return be


def available_backends() -> list[str]:
    """Names of backends that actually construct on this install."""
    out = []
    for name in sorted(_factories):
        try:
            get_backend(name)
        except BackendUnavailable:
            continue
        out.append(name)
    return out


def _default_name() -> str:
    return os.environ.get("REPRO_EXEC_BACKEND", DEFAULT_BACKEND)


def active_backend() -> Backend:
    global _active
    if _active is None:
        _active = _default_name()
    return get_backend(_active)


def set_backend(name: str) -> None:
    """Select the process-wide backend (validates availability now)."""
    global _active
    get_backend(name)
    _active = name


@contextmanager
def use_backend(name: str):
    """Temporarily select a backend (process-global, not thread-scoped)."""
    global _active
    prev = _active
    set_backend(name)
    try:
        yield get_backend(name)
    finally:
        _active = prev


def resolve(backend: "str | Backend | None") -> Backend:
    """Per-call dispatch: None -> active, str -> registry, Backend -> it."""
    if backend is None:
        return active_backend()
    if isinstance(backend, Backend):
        return backend
    return get_backend(backend)


def _reference_factory() -> Backend:
    from repro.exec.reference import ReferenceBackend
    return ReferenceBackend()


def _vectorized_factory() -> Backend:
    from repro.exec.vectorized import VectorizedBackend
    return VectorizedBackend()


def _jax_factory() -> Backend:
    from repro.exec.jax_backend import JaxBackend  # imports jax
    return JaxBackend()


def _sharded_factory() -> Backend:
    from repro.exec.sharded import ShardedBackend  # imports jax
    return ShardedBackend()


def _auto_factory() -> Backend:
    from repro.exec.auto import AutoBackend  # no hard deps
    return AutoBackend()


register("reference", _reference_factory)
register("vectorized", _vectorized_factory)
register("jax", _jax_factory)
register("sharded", _sharded_factory)
register("auto", _auto_factory)
