"""SQL front-door errors (control-plane moment, like every PlanError).

Both error classes subclass :class:`repro.core.errors.PlanError`: a
query that fails to parse or compile is an ill-typed pipeline, rejected
before any worker touches data ("ill-typed pipelines should not be
planned"). Unknown-name errors carry an edit-distance suggestion — the
one piece of UX the paper's agent story actually needs, because an
agent retries from the error text alone.

Message formats are pinned by tests (tests/test_sql_compiler.py); keep
them stable::

    unknown table 'userz' at ref 'main' (commit ab12...); did you mean
    'users'? known tables: ['orders', 'users']
    unknown column 'amout' in table 'orders' at ...; did you mean
    'amount'?
"""
from __future__ import annotations

from typing import Sequence

from repro.core.errors import PlanError

__all__ = ["SqlError", "SqlParseError", "SqlCompileError",
           "edit_distance", "suggest", "unknown_name"]

# a suggestion further than this many edits away is noise, not help
_MAX_SUGGEST_DISTANCE = 3


class SqlError(PlanError):
    """Base of all SQL front-door errors."""


class SqlParseError(SqlError):
    """The query text does not match the grammar (DESIGN.md §13)."""


class SqlCompileError(SqlError):
    """The query parsed but does not compile against the catalog/
    pipeline schemas (unknown names, type errors, shape violations)."""


def edit_distance(a: str, b: str) -> int:
    """Levenshtein distance (insert/delete/substitute, unit costs).

    Hand-rolled O(len(a)*len(b)) DP over two rows — names are short, so
    no banding needed; case-insensitive (SQL identifiers are)."""
    a, b = a.lower(), b.lower()
    if a == b:
        return 0
    if not a or not b:
        return len(a) + len(b)
    prev = list(range(len(b) + 1))
    for i, ca in enumerate(a, start=1):
        cur = [i]
        for j, cb in enumerate(b, start=1):
            cur.append(min(prev[j] + 1,          # delete from a
                           cur[j - 1] + 1,       # insert into a
                           prev[j - 1] + (ca != cb)))  # substitute
        prev = cur
    return prev[-1]


def suggest(name: str, candidates: Sequence[str]) -> str | None:
    """Nearest candidate within the suggestion radius, or None.

    Ties break lexicographically so the message is deterministic."""
    best: str | None = None
    best_d = _MAX_SUGGEST_DISTANCE + 1
    for cand in sorted(candidates):
        d = edit_distance(name, cand)
        if d < best_d:
            best, best_d = cand, d
    return best


def unknown_name(kind: str, name: str, candidates: Sequence[str],
                 context: str, *, where: str = "",
                 list_known: bool = False) -> SqlCompileError:
    """Build the pinned unknown-table/column error message."""
    msg = f"unknown {kind} {name!r}{where} at {context}"
    hint = suggest(name, candidates)
    if hint is not None:
        msg += f"; did you mean {hint!r}?"
    if list_known:
        msg += f" known {kind}s: {sorted(candidates)}"
    return SqlCompileError(msg)
