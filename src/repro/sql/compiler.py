"""AST -> logical-IR compiler for the SQL front door (DESIGN.md §13).

``compile_query`` turns a parsed :class:`repro.sql.ast.Query` plus the
input table contracts into a :class:`SqlNode` — a
:class:`~repro.core.dag.DeclarativeNode` carrying a pre-built logical
tree — and a *synthesized* output :class:`~repro.core.schema.Schema`
whose dtypes/nullability are inferred (:mod:`repro.sql.infer`), with
explicit lineage on every pass-through column so contract composition
(:func:`repro.core.contracts.check_node`) and Appendix-A elision see
exactly where each output column comes from.

Name resolution uses *scopes*: scope 0 is the FROM table, scope k the
k-th joined table. After a join the visible namespace is the union of
all scope columns with join keys merged onto the left spelling; when a
right-side column would collide with an earlier name, referenced
columns are renamed ``__q{k}_{col}`` behind a rename Project (internal
names only — they can never appear in an output contract) and
unreferenced collisions are dropped. An unqualified column appearing in
several scopes is accepted only when every occurrence is ON-equated
into one equivalence class (the join key merged them anyway); anything
else is ambiguous and must be qualified.

The compiled tree is canonical: two spellings of the same query (case,
whitespace, alias names that do not reach the output) produce the same
tree, the same ``describe()``, and therefore the same content-addressed
cache key. The query text itself is carried on the node for EXPLAIN
output but is *never* cache material.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, Mapping

from repro.core import logical as L
from repro.core import schema as S
from repro.core.dag import DeclarativeNode
from repro.data.tables import Expr, col, lit
from repro.sql import ast as A
from repro.sql.errors import SqlCompileError, unknown_name
from repro.sql.infer import (ColInfo, agg_result, dummy_table,
                             infer_expr, namespace_of)
from repro.sql.parser import parse

__all__ = ["SqlNode", "CompiledQuery", "compile_query"]


@dataclasses.dataclass(frozen=True)
class SqlNode(DeclarativeNode):
    """A declarative node compiled from SQL text.

    The body IS the compiled logical tree (``tree``); the inherited
    declarative fields (joins/filter/group/exprs) are populated
    faithfully so the planner's inspectability machinery
    (null-preservation, cast extraction, aggregate-output pruning)
    keeps working unchanged. ``query`` is display metadata only —
    ``source()`` describes the *tree*, so two spellings of one query
    share cache entries and a comment change can never force a rerun.
    """

    tree: Any = None
    query: str = ""

    def logical_tree(self):
        return self.tree

    def run(self, tables):
        return self.tree.execute(tables)

    def source(self) -> str:
        return f"<sql: {self.tree.describe()}>"


@dataclasses.dataclass(frozen=True)
class CompiledQuery:
    node: SqlNode
    output_schema: type[S.Schema]
    tables: tuple[str, ...]      # referenced input tables, FROM first


@dataclasses.dataclass(frozen=True)
class _Scope:
    index: int
    binding: str                 # alias, or the table name
    table: str
    schema: type[S.Schema]


def _walk(e: Any) -> Iterator[Any]:
    yield e
    if isinstance(e, A.BinOp):
        yield from _walk(e.left)
        yield from _walk(e.right)
    elif isinstance(e, (A.UnaryOp, A.IsNull)):
        yield from _walk(e.operand)
    elif isinstance(e, A.AggCall):
        yield from _walk(e.arg)


class _UnionFind:
    def __init__(self):
        self._parent: dict[Any, Any] = {}

    def find(self, x):
        p = self._parent.setdefault(x, x)
        if p != x:
            p = self._parent[x] = self.find(p)
        return p

    def union(self, a, b):
        self._parent[self.find(a)] = self.find(b)


_BIN_COMPILE: dict[str, Callable[[Expr, Expr], Expr]] = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "=": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "AND": lambda a, b: a & b,
    "OR": lambda a, b: a | b,
}


class _Compiler:
    def __init__(self, query_text: str, q: A.Query,
                 schemas: Mapping[str, type[S.Schema]], context: str):
        self.text = query_text
        self.q = q
        self.schemas = schemas
        self.context = context
        self.scopes: list[_Scope] = []
        self.bindings: dict[str, _Scope] = {}
        # output-name namespace after all joins:
        #   ns[out] = (owning scope index, source column)
        #   phys[(scope, src)] = out   (merged keys point at the left)
        self.ns: dict[str, tuple[int, str]] = {}
        self.phys: dict[tuple[int, str], str] = {}
        self.ns_info: dict[str, ColInfo] = {}   # out -> (dtype, nullable)
        self.referenced: dict[int, set[str]] = {}
        self.resolved: dict[A.ColumnRef, tuple[int, str]] = {}
        self.on_pairs: list[list[tuple[tuple[int, str],
                                       tuple[int, str]]]] = []
        self.equiv = _UnionFind()

    def err(self, msg: str) -> SqlCompileError:
        return SqlCompileError(f"{msg} at {self.context}")

    # -- scopes and resolution ------------------------------------------
    def build_scopes(self):
        refs = [self.q.from_table] + [j.table for j in self.q.joins]
        for i, tref in enumerate(refs):
            if tref.name not in self.schemas:
                raise unknown_name(
                    "table", tref.name, list(self.schemas),
                    self.context, list_known=True)
            if tref.binding in self.bindings:
                raise self.err(
                    f"duplicate table alias {tref.binding!r} "
                    f"(alias a self-join explicitly)")
            sc = _Scope(i, tref.binding, tref.name,
                        self.schemas[tref.name])
            self.scopes.append(sc)
            self.bindings[tref.binding] = sc
            self.referenced[i] = set()

    def _candidates(self, name: str) -> list[tuple[int, str]]:
        return [(sc.index, name) for sc in self.scopes
                if name in sc.schema.columns()]

    def resolve(self, ref: A.ColumnRef) -> tuple[int, str]:
        """Resolve a column reference to (scope index, source column)."""
        hit = self.resolved.get(ref)
        if hit is not None:
            return hit
        if ref.table is not None:
            sc = self.bindings.get(ref.table)
            if sc is None:
                raise unknown_name("table", ref.table,
                                   list(self.bindings), self.context)
            if ref.name not in sc.schema.columns():
                raise unknown_name(
                    "column", ref.name, list(sc.schema.columns()),
                    self.context, where=f" in table {sc.table!r}")
            out = (sc.index, ref.name)
        else:
            cands = self._candidates(ref.name)
            if not cands:
                everything = {c for sc in self.scopes
                              for c in sc.schema.columns()}
                raise unknown_name("column", ref.name,
                                   sorted(everything), self.context)
            if len(cands) > 1:
                roots = {self.equiv.find(c) for c in cands}
                if len(roots) > 1:
                    tables = [self.scopes[s].binding for s, _ in cands]
                    raise self.err(
                        f"ambiguous column {ref.name!r} (present in "
                        f"{tables}; qualify it)")
            out = cands[0]
        self.resolved[ref] = out
        self.referenced[out[0]].add(out[1])
        return out

    def orient_joins(self):
        """Resolve and orient every ON equality: one side must belong
        to the newly joined table, the other to an earlier scope."""
        for k, join in enumerate(self.q.joins, start=1):
            pairs: list[tuple[tuple[int, str], tuple[int, str]]] = []
            for a, b in join.on:
                ca = self._on_candidates(a, k)
                cb = self._on_candidates(b, k)
                pick = None
                for x in ca:
                    for y in cb:
                        if x[0] == k and y[0] < k:
                            pick = ((y, x), (x, y))   # (left,right),(a,b)
                        elif y[0] == k and x[0] < k:
                            pick = ((x, y), (x, y))
                        if pick:
                            break
                    if pick:
                        break
                if pick is None:
                    raise self.err(
                        f"join condition "
                        f"{a.display()} = {b.display()} must relate "
                        f"table {join.table.binding!r} to an earlier "
                        f"table")
                (left, right), (res_a, res_b) = pick
                self.resolved.setdefault(a, res_a)
                self.resolved.setdefault(b, res_b)
                pairs.append((left, right))
                self.referenced[left[0]].add(left[1])
                self.referenced[right[0]].add(right[1])
                self.equiv.union(left, right)
            self.on_pairs.append(pairs)

    def _on_candidates(self, ref: A.ColumnRef,
                       k: int) -> list[tuple[int, str]]:
        if ref.table is not None:
            sc = self.bindings.get(ref.table)
            if sc is None:
                raise unknown_name("table", ref.table,
                                   list(self.bindings), self.context)
            if sc.index > k:
                raise self.err(
                    f"join condition references {ref.display()!r} "
                    f"before table {sc.binding!r} is joined")
            if ref.name not in sc.schema.columns():
                raise unknown_name(
                    "column", ref.name, list(sc.schema.columns()),
                    self.context, where=f" in table {sc.table!r}")
            return [(sc.index, ref.name)]
        cands = [(s, c) for s, c in self._candidates(ref.name)
                 if s <= k]
        if not cands:
            everything = {c for sc in self.scopes
                          for c in sc.schema.columns()}
            raise unknown_name("column", ref.name, sorted(everything),
                               self.context)
        return cands

    def collect_references(self):
        """Resolve every column reference up front so namespace
        assignment knows which right-side columns must survive."""
        exprs: list[Any] = []
        for item in self.q.items:
            if isinstance(item.expr, A.Star):
                star = item.expr
                if star.table is None:
                    for sc in self.scopes:
                        self.referenced[sc.index].update(
                            sc.schema.columns())
                else:
                    sc = self.bindings.get(star.table)
                    if sc is None:
                        raise unknown_name(
                            "table", star.table, list(self.bindings),
                            self.context)
                    self.referenced[sc.index].update(
                        sc.schema.columns())
            else:
                exprs.append(item.expr)
        if self.q.where is not None:
            exprs.append(self.q.where)
        exprs.extend(self.q.group_by)
        for e in exprs:
            for node in _walk(e):
                if isinstance(node, A.ColumnRef):
                    self.resolve(node)

    # -- namespace assignment and join-tree construction -----------------
    def build_join_tree(self) -> L.LogicalOp:
        sc0 = self.scopes[0]
        for c, column in sc0.schema.columns().items():
            self.ns[c] = (0, c)
            self.phys[(0, c)] = c
            self.ns_info[c] = (column.dtype, column.nullable)
        op: L.LogicalOp = L.Scan(sc0.table)

        for k, join in enumerate(self.q.joins, start=1):
            sc = self.scopes[k]
            pairs = self.on_pairs[k - 1]
            key_map: dict[str, str] = {}     # right src -> output name
            on_names: list[str] = []
            for (ls, lc), (_, rc) in pairs:
                left_out = self.phys[(ls, lc)]
                if rc in key_map or left_out in key_map.values():
                    raise self.err(
                        f"duplicate join key in ON clause for table "
                        f"{sc.binding!r}")
                key_map[rc] = left_out
                on_names.append(left_out)

            cols = sc.schema.columns()
            keep = [c for c in cols
                    if c in key_map or c in self.referenced[k]]
            renames = {c: key_map[c] for c in key_map
                       if key_map[c] != c}
            collisions = [c for c in keep
                          if c not in key_map and c in self.ns]
            need_project = bool(renames) or bool(collisions)

            right: L.LogicalOp = L.Scan(sc.table)
            if need_project:
                rexprs: list[Expr] = []
                taken = set(self.ns)
                for c in cols:
                    if c in key_map:
                        dst = key_map[c]
                        rexprs.append(col(c).alias(dst))
                        self.phys[(k, c)] = dst
                        continue
                    if c not in self.referenced[k]:
                        continue             # unreferenced: dropped
                    dst = c
                    if dst in taken:
                        dst = f"__q{k}_{c}"
                        while dst in taken:
                            dst += "_"
                    taken.add(dst)
                    rexprs.append(col(c).alias(dst))
                    self.phys[(k, c)] = dst
                    self.ns[dst] = (k, c)
                    self.ns_info[dst] = (cols[c].dtype,
                                         cols[c].nullable
                                         or join.how == "left")
                right = L.Project(right, tuple(rexprs))
            else:
                for c, column in cols.items():
                    if c in key_map:         # same-named key: merged
                        self.phys[(k, c)] = key_map[c]
                        continue
                    self.phys[(k, c)] = c
                    self.ns[c] = (k, c)
                    self.ns_info[c] = (column.dtype,
                                       column.nullable
                                       or join.how == "left")
            op = L.Join(op, right, on=tuple(on_names), how=join.how)
        return op

    # -- scalar expression compilation ----------------------------------
    def compile_scalar(self, e: Any,
                       column: Callable[[A.ColumnRef], Expr],
                       agg: "Callable[[A.AggCall], Expr] | None" = None,
                       ) -> Expr:
        if isinstance(e, A.Literal):
            return lit(e.value)
        if isinstance(e, A.ColumnRef):
            return column(e)
        if isinstance(e, A.BinOp):
            return _BIN_COMPILE[e.op](
                self.compile_scalar(e.left, column, agg),
                self.compile_scalar(e.right, column, agg))
        if isinstance(e, A.UnaryOp):
            operand = self.compile_scalar(e.operand, column, agg)
            return ~operand if e.op == "NOT" else -operand
        if isinstance(e, A.IsNull):
            operand = self.compile_scalar(e.operand, column, agg)
            nn = operand.is_not_null()
            return nn if e.negated else ~nn
        if isinstance(e, A.AggCall):
            if agg is None:
                raise self.err(
                    f"aggregate {e.fn.upper()} is not allowed here "
                    f"(only in the select list of a GROUP BY query)")
            return agg(e)
        if isinstance(e, A.Star):
            raise self.err("'*' is not a scalar expression")
        raise self.err(f"unsupported expression {e!r}")   # pragma: no cover

    def ns_column(self, ref: A.ColumnRef) -> Expr:
        s, c = self.resolve(ref)
        return col(self.phys[(s, c)])

    # -- the main compile ------------------------------------------------
    def compile(self, *, name: str,
                schema_name: str | None) -> CompiledQuery:
        q = self.q
        self.build_scopes()
        self.orient_joins()
        self.collect_references()
        op = self.build_join_tree()

        filter_expr: Expr | None = None
        if q.where is not None:
            if any(isinstance(n, A.AggCall) for n in _walk(q.where)):
                raise self.err("aggregates are not allowed in WHERE")
            filter_expr = self.compile_scalar(q.where, self.ns_column)
            op = L.Filter(op, filter_expr)

        agg_calls = [n for item in q.items
                     if not isinstance(item.expr, A.Star)
                     for n in _walk(item.expr)
                     if isinstance(n, A.AggCall)]
        for call in agg_calls:
            if any(isinstance(n, A.AggCall) for n in _walk(call.arg)):
                raise self.err(
                    f"nested aggregate in {call.fn.upper()}(...)")

        group_keys: tuple[str, ...] = ()
        agg_specs: tuple[tuple[str, str, str], ...] = ()
        if q.group_by:
            if not agg_calls:
                raise self.err(
                    "GROUP BY requires at least one aggregate "
                    "(SUM/COUNT/MIN/MAX/MEAN) in the select list")
            op, group_keys, agg_specs, out_ns, item_exprs = \
                self._compile_grouped(op, agg_calls)
        elif agg_calls:
            raise self.err(
                f"aggregate {agg_calls[0].fn.upper()} requires "
                f"GROUP BY")
        else:
            out_ns, item_exprs = self._compile_plain()

        exprs = tuple(e for e, _ in item_exprs)
        op = L.Project(op, exprs)

        order_keys = self._order_keys(item_exprs)
        if order_keys:
            op = L.Sort(op, keys=order_keys)
        if q.limit is not None:
            op = L.Limit(op, q.limit)

        from repro.obs import get_recorder
        rec = get_recorder()
        if rec.enabled:
            # contract inference = dummy evaluation against the real
            # kernels — the one compile stage that executes anything.
            with rec.span("infer", items=len(item_exprs)):
                output_schema = self._synthesize_schema(
                    schema_name or f"{name}_schema", out_ns, item_exprs)
        else:
            output_schema = self._synthesize_schema(
                schema_name or f"{name}_schema", out_ns, item_exprs)
        tables = tuple(q.table_names())
        node = SqlNode(
            name=name,
            inputs={t: t for t in tables},
            input_schemas={t: self.schemas[t] for t in tables},
            output_schema=output_schema,
            exprs=exprs,
            filter_expr=filter_expr,
            joins=tuple(
                (self.scopes[k].table,
                 tuple(self.phys[(ls, lc)]
                       for (ls, lc), _ in self.on_pairs[k - 1]))
                for k in range(1, len(self.scopes))),
            join_how=("left" if any(j.how == "left" for j in q.joins)
                      else "inner"),
            group_keys=group_keys,
            agg_specs=agg_specs,
            tree=op,
            query=self.text)
        return CompiledQuery(node=node, output_schema=output_schema,
                             tables=tables)

    # -- plain (no GROUP BY) select list --------------------------------
    def _item_name(self, item: A.SelectItem, idx: int) -> str:
        if item.alias is not None:
            if item.alias.startswith("_"):
                raise self.err(
                    f"output column {item.alias!r} must not start "
                    f"with '_'")
            return item.alias
        if isinstance(item.expr, A.ColumnRef):
            return item.expr.name
        return f"col{idx}"

    def _compile_plain(self):
        """Returns (pre-projection namespace for inference,
        [(final Expr, origin (scope, src) | None), ...] in select
        order — with output names already applied via alias)."""
        items: list[tuple[Expr, tuple[int, str] | None]] = []
        names: list[str] = []
        for idx, item in enumerate(self.q.items):
            if isinstance(item.expr, A.Star):
                items.extend(self._expand_star(item.expr, names))
                continue
            out = self._item_name(item, idx)
            if isinstance(item.expr, A.ColumnRef):
                s, c = self.resolve(item.expr)
                phys = self.phys[(s, c)]
                origin = self.ns[phys]
                items.append((col(phys).alias(out), origin))
            else:
                e = self.compile_scalar(item.expr, self.ns_column)
                items.append((e.alias(out), None))
            names.append(out)
        self._check_dup(names)
        return dict(self.ns_info), items

    def _expand_star(self, star: A.Star, names: list[str]):
        out: list[tuple[Expr, tuple[int, str] | None]] = []
        if star.table is None:
            # bare *: the whole namespace, scope order, merged keys once
            for phys, (s, c) in self.ns.items():
                out.append((col(phys).alias(c), (s, c)))
                names.append(c)
        else:
            sc = self.bindings[star.table]
            for c in sc.schema.columns():
                phys = self.phys[(sc.index, c)]
                origin = self.ns[phys]
                out.append((col(phys).alias(c), origin))
                names.append(c)
        return out

    def _check_dup(self, names: list[str]):
        seen: set[str] = set()
        for n in names:
            if n in seen:
                raise self.err(
                    f"duplicate output column {n!r} in select list "
                    f"(alias or qualify it)")
            seen.add(n)

    # -- GROUP BY --------------------------------------------------------
    def _compile_grouped(self, op: L.LogicalOp,
                         agg_calls: list[A.AggCall]):
        q = self.q
        keys: list[str] = []
        key_origin: dict[str, tuple[int, str]] = {}
        for ref in q.group_by:
            s, c = self.resolve(ref)
            phys = self.phys[(s, c)]
            if phys not in keys:
                keys.append(phys)
                key_origin[phys] = self.ns[phys]

        # one spec per distinct (fn, structural arg) call
        calls: list[dict] = []
        by_key: dict[tuple[str, str], dict] = {}
        computed = 0
        for call in agg_calls:
            arg = self.compile_scalar(call.arg, self.ns_column)
            ck = (call.fn, arg.describe())
            if ck in by_key:
                continue
            simple = isinstance(call.arg, A.ColumnRef)
            if simple:
                value = arg.output_name()
            else:
                value = f"__agg{computed}"
                computed += 1
            entry = {"call": call, "fn": call.fn, "arg": arg,
                     "simple": simple, "value": value, "out": None}
            by_key[ck] = entry
            calls.append(entry)

        # pre-aggregation projection only when an argument is computed —
        # simple-column aggregations keep the hand-built tree shape
        # (Aggregate directly over the join/filter), sharing cache keys.
        if computed:
            pre: list[Expr] = [col(k) for k in keys]
            seen = set(keys)
            for entry in calls:
                if entry["simple"]:
                    if entry["value"] not in seen:
                        pre.append(col(entry["value"]))
                        seen.add(entry["value"])
                else:
                    pre.append(entry["arg"].alias(entry["value"]))
                    seen.add(entry["value"])
            op = L.Project(op, tuple(pre))

        # output names: select-item aliases win; unaliased simple calls
        # follow resolve_agg_specs' `{value}_{fn}` de-collided default
        # so SQL and the hand-built group_by().agg() path name (and
        # cache) identically.
        def call_of(e: Any) -> "dict | None":
            if not isinstance(e, A.AggCall):
                return None
            arg = self.compile_scalar(e.arg, self.ns_column)
            return by_key.get((e.fn, arg.describe()))

        used_outs = set(keys)

        def default_out(value: str, fn: str) -> str:
            out = f"{value}_{fn}"
            i = 1
            while out in used_outs:
                out = f"{value}_{fn}_{i}"
                i += 1
            return out

        for idx, item in enumerate(self.q.items):
            entry = call_of(item.expr)
            if entry is None or entry["out"] is not None:
                continue
            if item.alias is not None:
                if item.alias in used_outs:
                    raise self.err(
                        f"duplicate output column {item.alias!r} "
                        f"in select list (alias or qualify it)")
                if item.alias.startswith("_"):
                    raise self.err(
                        f"output column {item.alias!r} must not "
                        f"start with '_'")
                entry["out"] = item.alias
            elif entry["simple"]:
                entry["out"] = default_out(entry["value"], entry["fn"])
            else:
                entry["out"] = f"col{idx}"
            used_outs.add(entry["out"])
        for entry in calls:          # embedded-only calls: internal name
            if entry["out"] is None:
                entry["out"] = default_out(entry["value"], entry["fn"])
                used_outs.add(entry["out"])

        specs = tuple((e["fn"], e["value"], e["out"]) for e in calls)
        op = L.Aggregate(op, keys=tuple(keys), specs=specs)

        # post-aggregation namespace: keys pass through, aggregates by
        # the backend dtype contract.
        pre_dummy = dummy_table(self.ns_info)
        post_ns: dict[str, ColInfo] = {
            k: self.ns_info[k] for k in keys}
        for entry in calls:
            arg_info = infer_expr(
                entry["arg"], pre_dummy, context=self.context,
                what=f"{entry['fn'].upper()} argument")
            post_ns[entry["out"]] = agg_result(
                entry["fn"], arg_info, context=self.context,
                display=entry["arg"].describe())

        def post_column(ref: A.ColumnRef) -> Expr:
            s, c = self.resolve(ref)
            phys = self.phys[(s, c)]
            if phys not in keys:
                raise self.err(
                    f"column {ref.display()!r} must appear in GROUP "
                    f"BY or inside an aggregate")
            return col(phys)

        def post_agg(e: A.AggCall) -> Expr:
            entry = call_of(e)
            assert entry is not None
            return col(entry["out"])

        items: list[tuple[Expr, tuple[int, str] | None]] = []
        names: list[str] = []
        for idx, item in enumerate(self.q.items):
            if isinstance(item.expr, A.Star):
                raise self.err("'*' cannot be combined with GROUP BY")
            entry = call_of(item.expr)
            if entry is not None:
                out = item.alias or entry["out"]
                items.append((col(entry["out"]).alias(out), None))
            elif isinstance(item.expr, A.ColumnRef):
                out = self._item_name(item, idx)
                e = post_column(item.expr)
                items.append((e.alias(out), key_origin[e.output_name()]))
            else:
                out = self._item_name(item, idx)
                e = self.compile_scalar(item.expr, post_column,
                                        post_agg)
                items.append((e.alias(out), None))
            names.append(items[-1][0].output_name())
        self._check_dup(names)
        return op, tuple(keys), specs, post_ns, items

    # -- ORDER BY --------------------------------------------------------
    def _order_keys(self, item_exprs) -> tuple[tuple[str, bool], ...]:
        if not self.q.order_by:
            return ()
        out_names = [e.output_name() for e, _ in item_exprs]
        origins = {origin: e.output_name()
                   for e, origin in item_exprs if origin is not None}
        keys: list[tuple[str, bool]] = []
        for oi in self.q.order_by:
            ref = oi.ref
            if ref.table is None and ref.name in out_names:
                keys.append((ref.name, oi.ascending))
                continue
            # qualified (or aliased-away) ref: accept it when a bare
            # select item passes exactly that source column through.
            target = None
            try:
                s, c = self.resolve(ref)
            except SqlCompileError:
                s = c = None  # type: ignore[assignment]
            if c is not None:
                phys = self.phys.get((s, c))
                if phys is not None and phys in self.ns:
                    target = origins.get(self.ns[phys])
            if target is None:
                raise self.err(
                    f"ORDER BY column {ref.display()!r} must appear "
                    f"in the select list")
            keys.append((target, oi.ascending))
        return tuple(keys)

    # -- output contract synthesis ---------------------------------------
    def _synthesize_schema(self, schema_name: str,
                           out_ns: Mapping[str, ColInfo],
                           item_exprs) -> type[S.Schema]:
        dummy = dummy_table(out_ns)
        cols: dict[str, Any] = {}
        for e, origin in item_exprs:
            out = e.output_name()
            dtype, nullable = infer_expr(
                e, dummy, context=self.context,
                what=f"select item {e.describe()!r}")
            lineage = None
            if origin is not None:
                s, c = origin
                lineage = f"{self.scopes[s].schema.__name__}.{c}"
            cols[out] = S.Column(out, dtype, nullable=nullable,
                                 inherited_from=lineage)
        return S.Schema.of(schema_name, **cols)


def compile_query(query: str, *, name: str,
                  schemas: Mapping[str, type[S.Schema]],
                  context: str,
                  schema_name: str | None = None) -> CompiledQuery:
    """Parse + compile ``query`` against the given table contracts.

    ``schemas`` maps every *visible* table name to its contract (the
    catalog tables at a pinned ref, or a pipeline's sources + upstream
    node outputs); ``context`` names that universe in error messages
    (e.g. ``ref 'main' (commit ab12...)``). Raises
    :class:`~repro.sql.errors.SqlParseError` /
    :class:`~repro.sql.errors.SqlCompileError` — both PlanErrors: an
    ill-typed query is rejected at the control plane, before any
    worker touches data.
    """
    from repro.obs import get_recorder

    rec = get_recorder()
    if not rec.enabled:
        q = parse(query)
        return _Compiler(query, q, schemas, context).compile(
            name=name, schema_name=schema_name)
    with rec.span("parse"):
        q = parse(query)
    with rec.span("compile", tables=list(q.table_names())) as sp:
        compiled = _Compiler(query, q, schemas, context).compile(
            name=name, schema_name=schema_name)
        sp.set(output_schema=compiled.output_schema.__name__)
    return compiled
