"""Hand-written tokenizer for the SQL front door (DESIGN.md §13).

Deliberately tiny: identifiers, keywords (case-insensitive), integer /
float / single-quoted string literals (with ``''`` escaping), the
operator set the expression grammar needs, and punctuation. Every token
records its character offset so parse errors can point at the query.
"""
from __future__ import annotations

import dataclasses

from repro.sql.errors import SqlParseError

__all__ = ["Token", "tokenize", "KEYWORDS"]

KEYWORDS = frozenset({
    "SELECT", "FROM", "WHERE", "GROUP", "ORDER", "BY", "LIMIT",
    "JOIN", "INNER", "LEFT", "OUTER", "ON", "AS", "AND", "OR", "NOT",
    "IS", "NULL", "TRUE", "FALSE", "ASC", "DESC",
    "SUM", "COUNT", "MIN", "MAX", "MEAN", "AVG",
})

# longest-first so '<=' wins over '<', '<>' over '<'
_OPERATORS = ("<=", ">=", "<>", "!=", "==", "=", "<", ">",
              "+", "-", "*", "/")
_PUNCT = ("(", ")", ",", ".")


@dataclasses.dataclass(frozen=True)
class Token:
    kind: str      # KEYWORD | IDENT | INT | FLOAT | STRING | OP | PUNCT | EOF
    text: str      # keyword text is uppercased; idents keep their case
    pos: int       # character offset into the query


def tokenize(query: str) -> list[Token]:
    out: list[Token] = []
    i, n = 0, len(query)
    while i < n:
        ch = query[i]
        if ch.isspace():
            i += 1
            continue
        if ch == "'":
            j, chunks = i + 1, []
            while True:
                if j >= n:
                    raise SqlParseError(
                        f"unterminated string literal at position {i}")
                if query[j] == "'":
                    if j + 1 < n and query[j + 1] == "'":  # '' escape
                        chunks.append("'")
                        j += 2
                        continue
                    break
                chunks.append(query[j])
                j += 1
            out.append(Token("STRING", "".join(chunks), i))
            i = j + 1
            continue
        if ch.isdigit() or (ch == "." and i + 1 < n
                            and query[i + 1].isdigit()):
            j = i
            seen_dot = seen_exp = False
            while j < n:
                c = query[j]
                if c.isdigit():
                    j += 1
                elif c == "." and not seen_dot and not seen_exp:
                    seen_dot = True
                    j += 1
                elif c in "eE" and not seen_exp and j > i:
                    seen_exp = True
                    j += 1
                    if j < n and query[j] in "+-":
                        j += 1
                else:
                    break
            text = query[i:j]
            kind = "FLOAT" if (seen_dot or seen_exp) else "INT"
            out.append(Token(kind, text, i))
            i = j
            continue
        if ch.isalpha() or ch == "_":
            j = i
            while j < n and (query[j].isalnum() or query[j] == "_"):
                j += 1
            text = query[i:j]
            if text.upper() in KEYWORDS:
                out.append(Token("KEYWORD", text.upper(), i))
            else:
                out.append(Token("IDENT", text, i))
            i = j
            continue
        for op in _OPERATORS:
            if query.startswith(op, i):
                out.append(Token("OP", op, i))
                i += len(op)
                break
        else:
            if ch in _PUNCT:
                out.append(Token("PUNCT", ch, i))
                i += 1
            else:
                raise SqlParseError(
                    f"unexpected character {ch!r} at position {i}")
    out.append(Token("EOF", "", n))
    return out
