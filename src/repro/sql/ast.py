"""AST for the SQL front door — the parser's output, the compiler's input.

Plain frozen dataclasses, one per grammar production worth keeping.
Every node carries the ``pos`` of its first token so compile-time
errors (unknown column, type error) can point back into the query text.
"""
from __future__ import annotations

import dataclasses
from typing import Any

__all__ = ["ColumnRef", "Literal", "BinOp", "UnaryOp", "IsNull",
           "AggCall", "Star", "SelectItem", "TableRef", "JoinClause",
           "OrderItem", "Query"]


@dataclasses.dataclass(frozen=True)
class ColumnRef:
    table: str | None      # qualifier (alias or table name), or None
    name: str
    pos: int = 0

    def display(self) -> str:
        return f"{self.table}.{self.name}" if self.table else self.name


@dataclasses.dataclass(frozen=True)
class Literal:
    value: Any             # int | float | str | bool | None
    pos: int = 0


@dataclasses.dataclass(frozen=True)
class BinOp:
    op: str                # + - * / = != < <= > >= AND OR
    left: Any
    right: Any
    pos: int = 0


@dataclasses.dataclass(frozen=True)
class UnaryOp:
    op: str                # NOT | -
    operand: Any
    pos: int = 0


@dataclasses.dataclass(frozen=True)
class IsNull:
    operand: Any
    negated: bool          # True = IS NOT NULL
    pos: int = 0


@dataclasses.dataclass(frozen=True)
class AggCall:
    fn: str                # sum | count | min | max | mean
    arg: Any               # expression AST
    pos: int = 0


@dataclasses.dataclass(frozen=True)
class Star:
    table: str | None      # None = bare '*', else 'alias.*'
    pos: int = 0


@dataclasses.dataclass(frozen=True)
class SelectItem:
    expr: Any              # expression AST or Star
    alias: str | None
    pos: int = 0


@dataclasses.dataclass(frozen=True)
class TableRef:
    name: str
    alias: str | None
    pos: int = 0

    @property
    def binding(self) -> str:
        return self.alias or self.name


@dataclasses.dataclass(frozen=True)
class JoinClause:
    table: TableRef
    how: str                                    # "inner" | "left"
    on: tuple[tuple[ColumnRef, ColumnRef], ...]  # conjoined equalities
    pos: int = 0


@dataclasses.dataclass(frozen=True)
class OrderItem:
    ref: ColumnRef
    ascending: bool
    pos: int = 0


@dataclasses.dataclass(frozen=True)
class Query:
    items: tuple[SelectItem, ...]
    from_table: TableRef
    joins: tuple[JoinClause, ...]
    where: Any | None
    group_by: tuple[ColumnRef, ...]
    order_by: tuple[OrderItem, ...]
    limit: int | None

    def table_names(self) -> list[str]:
        """Referenced physical table names, FROM first, in query order."""
        seen: list[str] = [self.from_table.name]
        for j in self.joins:
            if j.table.name not in seen:
                seen.append(j.table.name)
        return seen
