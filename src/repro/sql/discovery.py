"""Catalog table discovery: snapshot manifest -> inferred contract.

``Client.sql`` queries tables *at a pinned ref*; those tables may have
been written by ``write_source_table`` without any declared contract.
Discovery synthesizes one from the snapshot's manifest alone — the
``to_blobs`` manifest records each column's storage kind and numpy
dtype, so no column blob is ever loaded to type a query (compile stays
a control-plane moment even against terabyte tables).

Nullability is read off the manifest too: a ``valid`` key is present
iff the column genuinely contains NULLs (``_ColumnData`` normalizes
all-valid masks away before serialization), so discovered contracts
are exact for the snapshot they describe. The synthesized schema class
is named after the *table* (not the snapshot), keeping lineage strings
— and with them output-contract fingerprints and cache keys — stable
across commits that only change data.
"""
from __future__ import annotations

from repro.core import schema as S
from repro.data.tables import _NP_TO_LOGICAL
from repro.sql.errors import SqlCompileError

__all__ = ["schema_from_snapshot"]


def schema_from_snapshot(store, snapshot: str,
                         table: str) -> type[S.Schema]:
    """Synthesize a :class:`~repro.core.schema.Schema` for one table
    snapshot by reading only its manifest."""
    manifest = store.get_json(snapshot)
    if manifest.get("kind") != "table":
        raise SqlCompileError(
            f"snapshot {snapshot!r} of table {table!r} is not a "
            f"table manifest")
    cols: dict[str, S.Column] = {}
    for name, m in manifest["columns"].items():
        kind = m["kind"]
        if kind == "str":
            logical = "str"
        elif kind == "datetime":
            logical = "datetime"
        else:
            # "plain": numeric/bool — dtype recorded since the SQL
            # front door landed; fall back to loading the array for
            # snapshots written before that.
            np_name = m.get("dtype")
            if np_name is None:         # pragma: no cover - legacy
                np_name = str(store.get_array(m["values"]).dtype)
            logical = _NP_TO_LOGICAL.get(np_name)
            if logical is None:
                raise SqlCompileError(
                    f"table {table!r} column {name!r}: unmapped "
                    f"physical dtype {np_name!r}")
        cols[name] = S.Column(name, S.as_dtype(logical),
                              nullable=m["valid"] is not None)
    return S.Schema.of(table, **cols)
