"""Schema inference for compiled SQL queries (DESIGN.md §13).

The contract a query publishes is *inferred, not trusted*: scalar
expressions are evaluated over a one-row dummy table built from the
input contracts (nullable columns get an all-invalid validity mask), so
the inferred dtype/nullability is whatever the house expression kernels
actually produce — inference and execution can never disagree, because
they run the same code. Aggregate outputs follow explicit rules that
mirror the backend contract (``repro.exec``, held bit-identical across
backends by the differential suite):

- ``count`` -> int64, never NULL;
- ``sum``   -> input dtype, NULL iff the input is nullable
  (an all-NULL group sums to NULL); int/float inputs only;
- ``mean``  -> float64 (SUM/COUNT finalized in float64), NULL iff the
  input is nullable; int/float inputs only;
- ``min``/``max`` -> input dtype, NULL iff the input is nullable;
  any input type (str/datetime compare lexicographically/temporally).

Group keys pass through unchanged — SQL groups all NULL keys into ONE
group, so a nullable key stays nullable.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from repro.core import schema as S
from repro.data.tables import Expr, Table, _ColumnData, _NP_TO_LOGICAL
from repro.sql.errors import SqlCompileError

__all__ = ["ColInfo", "dummy_table", "infer_expr", "agg_result"]

# (dtype, nullable) — the namespace entry for one visible column.
ColInfo = tuple[S.DType, bool]

_SAMPLE = {
    "int": 1, "float": 1.0, "bool": True,
}


def _sample_array(dtype: S.DType) -> np.ndarray:
    if dtype.family == "str":
        out = np.empty(1, dtype=object)
        out[0] = "a"
        return out
    if dtype.family == "datetime":
        return np.array(["2000-01-01"], dtype="datetime64[ns]")
    np_dtype = np.dtype(dtype.name)
    return np.array([_SAMPLE[dtype.family]], dtype=np_dtype)


def dummy_table(ns: Mapping[str, ColInfo]) -> Table:
    """One-row table matching a column namespace. Nullable columns are
    all-invalid so any expression touching them reports a nullable
    result — exactly the worst case the contract must cover."""
    data = {}
    for name, (dtype, nullable) in ns.items():
        valid = np.array([False]) if nullable else None
        data[name] = _ColumnData(_sample_array(dtype), valid)
    return Table(_data=data)


def infer_expr(expr: Expr, dummy: Table, *,
               context: str, what: str) -> ColInfo:
    """Dtype/nullability of ``expr`` by actually evaluating it."""
    try:
        vals, valid = expr.evaluate(dummy)
    except Exception as e:
        raise SqlCompileError(
            f"cannot type {what} at {context}: {e}") from e
    vals = np.asarray(vals)
    key = str(vals.dtype)
    logical = _NP_TO_LOGICAL.get(key)
    if logical is None and np.issubdtype(vals.dtype, np.datetime64):
        logical = "datetime"
    if logical is None:
        raise SqlCompileError(
            f"{what} at {context} produces unsupported dtype "
            f"{vals.dtype}")
    nullable = valid is not None and not bool(np.asarray(valid).all())
    return S.as_dtype(logical), nullable


def agg_result(fn: str, arg: ColInfo, *, context: str,
               display: str) -> ColInfo:
    """Output (dtype, nullable) of one aggregate call per the backend
    contract; raises on type-illegal aggregations."""
    dtype, nullable = arg
    if fn == "count":
        return S.INT64, False
    if fn in ("sum", "mean"):
        if dtype.family not in ("int", "float"):
            raise SqlCompileError(
                f"{fn.upper()}({display}) at {context}: requires a "
                f"numeric argument, got {dtype.name}")
        return (S.FLOAT64 if fn == "mean" else dtype), nullable
    if fn in ("min", "max"):
        return dtype, nullable
    raise SqlCompileError(              # pragma: no cover - parser gates
        f"unknown aggregate {fn!r} at {context}")


def schema_columns(ns: Mapping[str, ColInfo]) -> dict[str, S.Column]:
    """Namespace -> fresh Column objects (no lineage)."""
    return {name: S.Column(name, dtype, nullable=nullable)
            for name, (dtype, nullable) in ns.items()}


def namespace_of(schema: type[S.Schema],
                 columns: Sequence[str] | None = None
                 ) -> dict[str, ColInfo]:
    """Contract -> namespace mapping."""
    cols = schema.columns()
    names = columns if columns is not None else list(cols)
    return {n: (cols[n].dtype, cols[n].nullable) for n in names}
