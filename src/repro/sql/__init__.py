"""SQL front door (DESIGN.md §13): parse -> logical IR -> optimized plan.

A hand-written tokenizer + recursive-descent parser for single-SELECT
queries (joins, WHERE, GROUP BY aggregates, ORDER BY, LIMIT), an
AST-to-:mod:`repro.core.logical` compiler with contract-inferred output
schemas, and catalog table discovery — so ``Client.sql(query, ref=...)``
and ``Pipeline.sql_query(name=..., query=...)`` are thin front ends
over the *existing* planner, optimizer, cache, and backends: every
query flows through ``optimize()``, executes on the stats-driven
``auto`` backend, and caches content-addressed by its logical tree
(two spellings of one query share an entry; the query text is EXPLAIN
metadata, never key material).
"""
from repro.sql.ast import Query
from repro.sql.compiler import CompiledQuery, SqlNode, compile_query
from repro.sql.discovery import schema_from_snapshot
from repro.sql.errors import (SqlCompileError, SqlError, SqlParseError,
                              edit_distance, suggest)
from repro.sql.parser import parse
from repro.sql.tokens import Token, tokenize

__all__ = ["parse", "tokenize", "Token", "Query", "compile_query",
           "CompiledQuery", "SqlNode", "schema_from_snapshot",
           "SqlError", "SqlParseError", "SqlCompileError",
           "edit_distance", "suggest"]
