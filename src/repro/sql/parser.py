"""Recursive-descent parser for the SQL front door (DESIGN.md §13).

Grammar (one SELECT statement, no subqueries)::

    query      := SELECT select_list FROM table_ref join* where?
                  group? order? limit?
    select_list:= '*' | item (',' item)*
    item       := ident '.' '*' | expr ((AS)? ident)?
    table_ref  := ident ((AS)? ident)?
    join       := ((INNER | LEFT (OUTER)?))? JOIN table_ref ON on_cond
    on_cond    := col_eq (AND col_eq)*
    col_eq     := colref '=' colref
    where      := WHERE expr
    group      := GROUP BY colref (',' colref)*
    order      := ORDER BY colref (ASC|DESC)? (',' ...)*
    limit      := LIMIT INT
    expr       := or ; or := and (OR and)* ; and := not (AND not)*
    not        := NOT not | cmp
    cmp        := add (cmpop add)? | add IS (NOT)? NULL
    cmpop      := '=' | '==' | '!=' | '<>' | '<' | '<=' | '>' | '>='
    add        := mul (('+'|'-') mul)*
    mul        := unary (('*'|'/') unary)*
    unary      := '-' unary | primary
    primary    := literal | aggcall | colref | '(' expr ')'
    aggcall    := (SUM|COUNT|MIN|MAX|MEAN|AVG) '(' expr ')'
    colref     := ident ('.' ident)?
    literal    := INT | FLOAT | STRING | TRUE | FALSE | NULL

ON conditions are restricted to conjunctions of column equalities —
that is exactly what the logical ``Join`` op (and every backend hash
join) supports, so the restriction is honest rather than a parser
shortcut. ``AVG`` is accepted as a synonym for ``MEAN``.
"""
from __future__ import annotations

from repro.sql import ast as A
from repro.sql.errors import SqlParseError
from repro.sql.tokens import Token, tokenize

__all__ = ["parse"]

_AGG_FNS = {"SUM": "sum", "COUNT": "count", "MIN": "min",
            "MAX": "max", "MEAN": "mean", "AVG": "mean"}
_CMP_OPS = {"=": "=", "==": "=", "!=": "!=", "<>": "!=",
            "<": "<", "<=": "<=", ">": ">", ">=": ">="}


class _Parser:
    def __init__(self, query: str):
        self.query = query
        self.toks = tokenize(query)
        self.i = 0

    # -- token plumbing -------------------------------------------------
    @property
    def cur(self) -> Token:
        return self.toks[self.i]

    def advance(self) -> Token:
        t = self.cur
        self.i += 1
        return t

    def at_kw(self, *kws: str) -> bool:
        return self.cur.kind == "KEYWORD" and self.cur.text in kws

    def take_kw(self, *kws: str) -> bool:
        if self.at_kw(*kws):
            self.advance()
            return True
        return False

    def expect_kw(self, kw: str) -> Token:
        if not self.at_kw(kw):
            self.fail(f"expected {kw}")
        return self.advance()

    def at(self, kind: str, text: str | None = None) -> bool:
        return (self.cur.kind == kind
                and (text is None or self.cur.text == text))

    def take(self, kind: str, text: str | None = None) -> bool:
        if self.at(kind, text):
            self.advance()
            return True
        return False

    def expect(self, kind: str, text: str | None = None,
               what: str | None = None) -> Token:
        if not self.at(kind, text):
            self.fail(f"expected {what or text or kind}")
        return self.advance()

    def fail(self, what: str):
        t = self.cur
        got = "end of query" if t.kind == "EOF" else repr(t.text)
        raise SqlParseError(
            f"syntax error at position {t.pos}: {what}, got {got}")

    def ident(self, what: str = "identifier") -> Token:
        if self.cur.kind != "IDENT":
            self.fail(f"expected {what}")
        return self.advance()

    # -- productions ----------------------------------------------------
    def parse(self) -> A.Query:
        self.expect_kw("SELECT")
        items = self.select_list()
        self.expect_kw("FROM")
        from_table = self.table_ref()
        joins = []
        while self.at_kw("JOIN", "INNER", "LEFT"):
            joins.append(self.join_clause())
        where = None
        if self.take_kw("WHERE"):
            where = self.expr()
        group_by: tuple[A.ColumnRef, ...] = ()
        if self.at_kw("GROUP"):
            self.advance()
            self.expect_kw("BY")
            group_by = tuple(self.colref_list())
        order_by: list[A.OrderItem] = []
        if self.at_kw("ORDER"):
            self.advance()
            self.expect_kw("BY")
            while True:
                ref = self.colref()
                asc = True
                if self.take_kw("DESC"):
                    asc = False
                else:
                    self.take_kw("ASC")
                order_by.append(A.OrderItem(ref, asc, ref.pos))
                if not self.take("PUNCT", ","):
                    break
        limit = None
        if self.take_kw("LIMIT"):
            tok = self.expect("INT", what="an integer LIMIT")
            limit = int(tok.text)
        if self.cur.kind != "EOF":
            self.fail("expected end of query")
        return A.Query(items=tuple(items), from_table=from_table,
                       joins=tuple(joins), where=where,
                       group_by=group_by, order_by=tuple(order_by),
                       limit=limit)

    def select_list(self) -> list[A.SelectItem]:
        items = []
        while True:
            pos = self.cur.pos
            if self.take("OP", "*"):
                items.append(A.SelectItem(A.Star(None, pos), None, pos))
            elif (self.cur.kind == "IDENT"
                  and self.toks[self.i + 1].kind == "PUNCT"
                  and self.toks[self.i + 1].text == "."
                  and self.toks[self.i + 2].kind == "OP"
                  and self.toks[self.i + 2].text == "*"):
                qual = self.advance().text
                self.advance()          # '.'
                self.advance()          # '*'
                items.append(A.SelectItem(A.Star(qual, pos), None, pos))
            else:
                e = self.expr()
                alias = None
                if self.take_kw("AS"):
                    alias = self.ident("output name after AS").text
                elif self.cur.kind == "IDENT":
                    alias = self.advance().text
                items.append(A.SelectItem(e, alias, pos))
            if not self.take("PUNCT", ","):
                return items

    def table_ref(self) -> A.TableRef:
        name = self.ident("table name")
        alias = None
        if self.take_kw("AS"):
            alias = self.ident("table alias after AS").text
        elif self.cur.kind == "IDENT":
            alias = self.advance().text
        return A.TableRef(name.text, alias, name.pos)

    def join_clause(self) -> A.JoinClause:
        pos = self.cur.pos
        how = "inner"
        if self.take_kw("LEFT"):
            how = "left"
            self.take_kw("OUTER")
        else:
            self.take_kw("INNER")
        self.expect_kw("JOIN")
        table = self.table_ref()
        self.expect_kw("ON")
        conds = [self.col_eq()]
        while self.take_kw("AND"):
            conds.append(self.col_eq())
        return A.JoinClause(table, how, tuple(conds), pos)

    def col_eq(self) -> tuple[A.ColumnRef, A.ColumnRef]:
        left = self.colref("a join key column")
        self.expect("OP", "=", "'=' between join key columns")
        right = self.colref("a join key column")
        return left, right

    def colref(self, what: str = "a column reference") -> A.ColumnRef:
        tok = self.ident(what)
        if self.at("PUNCT", "."):
            self.advance()
            name = self.ident("column name after '.'")
            return A.ColumnRef(tok.text, name.text, tok.pos)
        return A.ColumnRef(None, tok.text, tok.pos)

    def colref_list(self) -> list[A.ColumnRef]:
        refs = [self.colref()]
        while self.take("PUNCT", ","):
            refs.append(self.colref())
        return refs

    # expression precedence ladder
    def expr(self):
        return self.or_expr()

    def or_expr(self):
        left = self.and_expr()
        while self.at_kw("OR"):
            pos = self.advance().pos
            left = A.BinOp("OR", left, self.and_expr(), pos)
        return left

    def and_expr(self):
        left = self.not_expr()
        while self.at_kw("AND"):
            pos = self.advance().pos
            left = A.BinOp("AND", left, self.not_expr(), pos)
        return left

    def not_expr(self):
        if self.at_kw("NOT"):
            pos = self.advance().pos
            return A.UnaryOp("NOT", self.not_expr(), pos)
        return self.cmp_expr()

    def cmp_expr(self):
        left = self.add_expr()
        if self.at_kw("IS"):
            pos = self.advance().pos
            negated = bool(self.take_kw("NOT"))
            self.expect_kw("NULL")
            return A.IsNull(left, negated, pos)
        if self.cur.kind == "OP" and self.cur.text in _CMP_OPS:
            tok = self.advance()
            return A.BinOp(_CMP_OPS[tok.text], left, self.add_expr(),
                           tok.pos)
        return left

    def add_expr(self):
        left = self.mul_expr()
        while self.at("OP", "+") or self.at("OP", "-"):
            tok = self.advance()
            left = A.BinOp(tok.text, left, self.mul_expr(), tok.pos)
        return left

    def mul_expr(self):
        left = self.unary()
        while self.at("OP", "*") or self.at("OP", "/"):
            tok = self.advance()
            left = A.BinOp(tok.text, left, self.unary(), tok.pos)
        return left

    def unary(self):
        if self.at("OP", "-"):
            pos = self.advance().pos
            return A.UnaryOp("-", self.unary(), pos)
        return self.primary()

    def primary(self):
        t = self.cur
        if t.kind == "INT":
            self.advance()
            return A.Literal(int(t.text), t.pos)
        if t.kind == "FLOAT":
            self.advance()
            return A.Literal(float(t.text), t.pos)
        if t.kind == "STRING":
            self.advance()
            return A.Literal(t.text, t.pos)
        if t.kind == "KEYWORD":
            if t.text in ("TRUE", "FALSE"):
                self.advance()
                return A.Literal(t.text == "TRUE", t.pos)
            if t.text == "NULL":
                self.advance()
                return A.Literal(None, t.pos)
            if t.text in _AGG_FNS:
                self.advance()
                self.expect("PUNCT", "(")
                if t.text == "COUNT" and self.at("OP", "*"):
                    self.fail("COUNT(*) is not supported; "
                              "COUNT a column instead")
                arg = self.expr()
                self.expect("PUNCT", ")")
                return A.AggCall(_AGG_FNS[t.text], arg, t.pos)
            self.fail("expected an expression")
        if t.kind == "IDENT":
            return self.colref()
        if self.take("PUNCT", "("):
            e = self.expr()
            self.expect("PUNCT", ")")
            return e
        self.fail("expected an expression")


def parse(query: str) -> A.Query:
    """Parse one SELECT statement into a :class:`repro.sql.ast.Query`."""
    if not query or not query.strip():
        raise SqlParseError("empty query")
    return _Parser(query).parse()
