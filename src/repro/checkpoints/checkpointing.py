"""Versioned, transactional checkpointing — the paper's §3.3 protocol
applied to training state.

A training checkpoint is a *multi-table commit*: ``params``,
``opt_state``, ``data_state`` (pipeline cursor) and ``metrics`` must be
published atomically — a restart that mixes params@N with cursor@N−k is
exactly the torn state of paper Fig. 3. The manager therefore writes all
four artifacts inside one :class:`TransactionalRun`, runs verifiers
(finite-params check = the "data quality" gate), and merges atomically.

Branches give the full Git-for-data workflow on checkpoints: train on a
feature branch, tag milestones, merge to main when evals pass, reproduce
any run from its pinned commit.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax
import numpy as np

from repro.core.catalog import Catalog
from repro.core.errors import QualityError
from repro.core.store import ObjectStore, get_pytree, put_pytree
from repro.core.transactions import RunRegistry, TransactionalRun

TABLES = ("params", "opt_state", "data_state", "metrics")


@dataclasses.dataclass(frozen=True)
class CheckpointRef:
    step: int
    commit: str
    run_id: str


class CheckpointManager:
    def __init__(self, catalog: Catalog, *, branch: str = "main",
                 registry: RunRegistry | None = None,
                 check_finite: bool = True):
        self.catalog = catalog
        self.store: ObjectStore = catalog.store
        self.branch = branch
        self.registry = registry or RunRegistry()
        self.check_finite = check_finite

    # ------------------------------------------------------------------
    def save(self, *, step: int, params: Any, opt_state: Any,
             data_state: dict, metrics: dict,
             code: str = "") -> CheckpointRef:
        """Atomically publish a checkpoint (all four tables or none)."""
        host_params = jax.tree.map(np.asarray, params)
        host_opt = jax.tree.map(np.asarray, opt_state)

        with TransactionalRun(self.catalog, self.branch, code=code,
                              registry=self.registry,
                              run_id=f"ckpt_{step}") as txn:
            if self.check_finite:
                for leaf in jax.tree.leaves(host_params):
                    if np.issubdtype(leaf.dtype, np.floating) and \
                            not np.isfinite(
                                leaf.astype(np.float32)).all():
                        raise QualityError(
                            f"checkpoint step {step}: non-finite params")
            # all four artifacts in ONE commit: the branch log shows one
            # entry per checkpoint, and no reader can see a prefix.
            txn.write_tables({
                "params": put_pytree(self.store, host_params),
                "opt_state": put_pytree(self.store, host_opt),
                "data_state": self.store.put_json(
                    {"step": step, **data_state}),
                "metrics": self.store.put_json(
                    {"step": step,
                     **{k: float(v) for k, v in metrics.items()}}),
            }, message=f"checkpoint@{step}")
        # the merged commit from the txn itself — NOT head(branch), which
        # may already reflect a later concurrent checkpoint.
        assert txn.final_commit is not None
        return CheckpointRef(step=step, commit=txn.final_commit.id,
                             run_id=f"ckpt_{step}")

    # ------------------------------------------------------------------
    def restore(self, like_params: Any, like_opt: Any, *,
                ref: str | None = None
                ) -> tuple[Any, Any, dict, dict] | None:
        """Load the latest checkpoint from ``ref`` (default: the branch).

        Guaranteed consistent: all four tables come from ONE commit."""
        ref = ref or self.branch
        head = self.catalog.head(ref)
        if "params" not in head.tables:
            return None
        params = get_pytree(self.store, head.tables["params"], like_params)
        opt = get_pytree(self.store, head.tables["opt_state"], like_opt)
        data_state = self.store.get_json(head.tables["data_state"])
        metrics = self.store.get_json(head.tables["metrics"])
        return params, opt, data_state, metrics

    def latest_step(self, ref: str | None = None) -> int | None:
        head = self.catalog.head(ref or self.branch)
        if "data_state" not in head.tables:
            return None
        return int(self.store.get_json(head.tables["data_state"])["step"])
