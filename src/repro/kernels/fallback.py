"""Shared numpy-fallback plumbing for the accelerator kernels.

The jax-backed execution paths (``exec.jax_backend`` aggregation,
``exec.sharded`` joins, ``kernels/hash_join`` probes) cannot represent
every table dtype on the device: object columns never lower, and 64-bit
numerics silently truncate to 32 bits unless ``jax_enable_x64`` is on
(the JAX default is off). Truncation would be a *correctness* bug, so
those paths fall back to the numpy implementation instead — but a
silent fallback is a perf bug that nobody ever notices. Every fallback
decision therefore routes through :func:`device_supports_dtype`, and
the first x64-induced fallback per (op, dtype) emits a
``warnings.warn`` naming the env fix, so degraded performance is
observable without spamming one warning per batch.

When tracing is on, EVERY fallback (not just the first) additionally
lands as a structured ``degradation`` event on the active recorder —
recorded *before* the one-time dedup check — so run manifests show all
degradations a run suffered while the interactive warning stays
one-shot (DESIGN.md §14).
"""
from __future__ import annotations

import threading
import warnings

import numpy as np

from repro.obs import get_recorder

__all__ = ["device_supports_dtype", "warn_numpy_fallback",
           "reset_fallback_warnings", "NumpyFallbackWarning"]


class NumpyFallbackWarning(UserWarning):
    """An accelerator path degraded to numpy (correctness-preserving)."""


_lock = threading.Lock()
_warned: set[tuple[str, str]] = set()


def device_supports_dtype(dtype: np.dtype) -> bool:
    """Can this dtype run on the device without losing bits?

    Object columns and non-numeric kinds never lower; 64-bit numerics
    need ``jax_enable_x64``. Callers that get ``False`` must take the
    numpy path and SHOULD call :func:`warn_numpy_fallback` when the
    cause is the x64 flag (i.e. the user could fix it with one env
    var).
    """
    dtype = np.dtype(dtype)
    if dtype == object or dtype.kind not in "iuf":
        return False
    if dtype.itemsize > 4:
        import jax
        return bool(jax.config.jax_enable_x64)
    return True


def x64_is_the_fix(dtype: np.dtype) -> bool:
    """True when the ONLY reason ``dtype`` cannot lower is the x64 flag."""
    dtype = np.dtype(dtype)
    return dtype != object and dtype.kind in "iuf" and dtype.itemsize > 4


def warn_numpy_fallback(op: str, dtype: np.dtype, *,
                        reason: str | None = None) -> None:
    """One-time (per op × dtype) warning that a device path degraded to
    numpy. Names the env fix when the x64 flag is the cause."""
    dtype = np.dtype(dtype)
    rec = get_recorder()
    if rec.enabled:
        # before the dedup check: manifests record every degradation,
        # only the interactive warning is one-shot.
        rec.event("degradation", kind="numpy_fallback", op=op,
                  dtype=dtype.str,
                  reason=reason if reason is not None else (
                      "x64 disabled" if x64_is_the_fix(dtype)
                      else "dtype not device-representable"))
        rec.metrics.counter("exec.numpy_fallbacks").inc()
    key = (op, dtype.str)
    with _lock:
        if key in _warned:
            return
        _warned.add(key)
    if reason is None:
        if x64_is_the_fix(dtype):
            reason = ("jax_enable_x64 is off; enable it (e.g. "
                      "JAX_ENABLE_X64=1 or "
                      "jax.config.update('jax_enable_x64', True)) to run "
                      "this dtype on the device")
        else:
            reason = "dtype cannot be represented on the device"
    warnings.warn(
        f"{op}: falling back to the numpy path for dtype {dtype} — "
        f"{reason}. Results are identical; only performance degrades.",
        NumpyFallbackWarning, stacklevel=3)


def reset_fallback_warnings() -> None:
    """Test hook: forget which (op, dtype) pairs already warned."""
    with _lock:
        _warned.clear()
