"""RG-LRU linear-recurrence Pallas TPU kernel.

The recurrence h_t = a_t ⊙ h_{t-1} + b_t is element-wise over the width
W, so the TPU adaptation (DESIGN.md §7) blocks W across the *parallel*
grid dimension (8×128 VPU lanes) and runs the sequence dimension as the
*sequential* minor grid dimension, carrying the running state h in VMEM
scratch. Within a (block_s × block_w) tile we do a **log-depth blocked
associative scan** (Blelloch-style up-sweep on (a,b) pairs) rather than a
per-element loop — O(log block_s) VPU sweeps instead of O(block_s).

Grid: (n_w, n_s) — n_s minor ⇒ state carried tile-to-tile.
BlockSpec tiles: a/b (B, block_s, block_w) staged HBM→VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_body(a_ref, b_ref, h_ref, carry_ref, *, block_s):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[...]            # (B, block_s, block_w)
    b = b_ref[...]

    # log-depth inclusive scan of the affine maps (a, b) over axis 1:
    # compose (a2,b2)∘(a1,b1) = (a1*a2, a2*b1 + b2)
    n = 1
    while n < block_s:
        a_shift = jnp.pad(a, ((0, 0), (n, 0), (0, 0)),
                          constant_values=1.0)[:, :-n, :]
        b_shift = jnp.pad(b, ((0, 0), (n, 0), (0, 0)))[:, :-n, :]
        b = a * b_shift + b
        a = a * a_shift
        n *= 2

    # fold in carried state: h_t = A_t * h_in + B_t
    h_in = carry_ref[...]                     # (B, block_w)
    h = a * h_in[:, None, :] + b
    h_ref[...] = h
    carry_ref[...] = h[:, -1, :]


def rglru_scan_kernel(a: jax.Array, b: jax.Array, *,
                      block_s: int = 256, block_w: int = 128,
                      interpret: bool = True) -> jax.Array:
    """a, b: (B, S, W) float32 -> h (B, S, W)."""
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    pad_s = (-S) % block_s
    pad_w = (-W) % block_w
    if pad_s or pad_w:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_w)))
    n_s = a.shape[1] // block_s
    n_w = a.shape[2] // block_w

    out = pl.pallas_call(
        functools.partial(_rglru_body, block_s=block_s),
        grid=(n_w, n_s),
        in_specs=[
            pl.BlockSpec((B, block_s, block_w), lambda wi, si: (0, si, wi)),
            pl.BlockSpec((B, block_s, block_w), lambda wi, si: (0, si, wi)),
        ],
        out_specs=pl.BlockSpec((B, block_s, block_w),
                               lambda wi, si: (0, si, wi)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        scratch_shapes=[pltpu.VMEM((B, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:, :S, :W]
