"""Pure-jnp oracle for the RG-LRU recurrence kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array,
                   h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t, sequential oracle.

    a, b: (B, S, W) float32; h0: (B, W) or None.
    """
    B, S, W = a.shape
    h = h0 if h0 is not None else jnp.zeros((B, W), a.dtype)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    _, hs = jax.lax.scan(step, h, (a.transpose(1, 0, 2),
                                   b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
