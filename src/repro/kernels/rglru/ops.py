"""Jit'd wrapper for the RG-LRU recurrence kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru.kernel import rglru_scan_kernel
from repro.kernels.rglru.ref import rglru_scan_ref


@functools.partial(jax.jit, static_argnames=("block_s", "block_w",
                                             "use_pallas", "interpret"))
def rglru_scan(a, b, *, block_s: int = 256, block_w: int = 128,
               use_pallas: bool = True, interpret: bool = True):
    if not use_pallas:
        return rglru_scan_ref(a, b)
    return rglru_scan_kernel(a, b, block_s=block_s, block_w=block_w,
                             interpret=interpret)
