"""Pure-jnp oracle for the hash-probe kernel (the join inner loop).

The probe primitive answers, for every probe-side row, "where do my
matches live?" against a *grouped build layout*: the build side's rows
sorted by key slot, so all rows of one key are contiguous. The table is
an open-addressing (start, count) slot array addressed by the key's
slot. Because the execution backends probe *dense codes* produced by
the joint key factorization (``exec.vectorized._join_codes``) rebased
to the shard's key range, the hash is perfect — slot = code - base,
collision chains have length one by construction — which is what lets
the Pallas kernel probe with a single masked lookup per lane while
keeping the (key, start, count) slot layout that a chained probe over
non-dense keys would need.

``build_probe_table`` builds the table from the slot array of the
*sorted* build side: per-slot counts by scatter-add, per-slot starts by
exclusive cumsum (valid exactly because the build rows are sorted by
slot, so a slot's run begins after all smaller slots' rows).
``hash_probe_ref`` is the XLA gather lookup — the oracle the Pallas
kernel must reproduce exactly (int32 in, int32 out: no float, no
carve-out).
"""
from __future__ import annotations

import jax.numpy as jnp


def build_probe_table(slots_sorted, table_size: int):
    """(table_start, table_count) int32 arrays of length ``table_size``.

    ``slots_sorted``: (m,) int32 — shard-local slot per build row,
    ascending over valid rows; invalid rows carry a slot outside
    ``[0, table_size)`` (they sort to the end and are dropped by the
    scatter). Empty slots read (start=whatever, count=0) — the probe
    masks on count.
    """
    slots_sorted = slots_sorted.astype(jnp.int32)
    in_range = (slots_sorted >= 0) & (slots_sorted < table_size)
    idx = jnp.where(in_range, slots_sorted, table_size)
    counts = jnp.zeros(table_size, jnp.int32).at[idx].add(
        1, mode="drop")
    starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    return starts, counts


def hash_probe_ref(table_start, table_count, probe_slots):
    """Masked probe: per probe lane, the (start, count) of its match run
    in the slot-sorted build array; lanes whose slot is outside the
    table (NULL/NaN keys, other shards' key ranges, padding) emit
    count 0 — the ragged-match emission happens one level up, on the
    host, exactly like the vectorized backend's expansion.
    """
    table_size = table_start.shape[0]
    probe_slots = probe_slots.astype(jnp.int32)
    ok = (probe_slots >= 0) & (probe_slots < table_size)
    idx = jnp.where(ok, probe_slots, 0)
    starts = jnp.where(ok, table_start[idx], 0)
    counts = jnp.where(ok, table_count[idx], 0)
    return starts, counts


def masked_hash_probe_ref(table_start, table_count, probe_slots,
                          probe_mask):
    """Filter-fused probe oracle: like :func:`hash_probe_ref` but lanes
    whose ``probe_mask`` entry is falsy emit (0, 0) regardless of their
    slot — the probe-side filter applied *inside* the lookup, so a
    fused ``filter → join`` never materializes the filtered rows.
    Equivalent to ``hash_probe_ref(ts, tc, where(mask, slots, -1))``;
    kept as a separate primitive so the Pallas kernel's in-VMEM mask
    path has an XLA oracle to match bit for bit.
    """
    starts, counts = hash_probe_ref(table_start, table_count,
                                    probe_slots)
    keep = probe_mask.astype(jnp.bool_)
    zero = jnp.zeros((), jnp.int32)
    return (jnp.where(keep, starts, zero),
            jnp.where(keep, counts, zero))
