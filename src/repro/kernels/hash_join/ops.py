"""Public hash-probe wrapper: XLA gather, Pallas kernel, or numpy.

Mirrors ``kernels/segment_sum/ops.py``: ``use_pallas=False`` (default)
lowers the probe to the XLA gather oracle (``ref.hash_probe_ref``);
``use_pallas=True`` runs the tiled one-hot kernel (``interpret=True``
on CPU containers — TPU is the compile target). Both are jit-friendly
and are what ``exec.sharded`` calls *inside* its ``shard_map`` body, so
the per-shard probe inner loop runs on the device that owns the shard.

:func:`hash_probe_np` / :func:`build_probe_table_np` are the numpy
floor: bit-identical to the oracle and importable without JAX, so
:func:`hash_probe` stays callable on JAX-less installs (the sharded
backend itself never reaches that branch — it cannot construct
without JAX; ``kernels.fallback`` degrades its *key coding* upstream
instead — but the differential tests and any host-side caller probe
through the same contract). Slot arrays are int32 by construction
(dense codes are bounded by the row count, which the sharded backend
caps at 2**31), so the probe itself never needs x64.
"""
from __future__ import annotations

import functools

import numpy as np


def hash_probe_np(table_start: np.ndarray, table_count: np.ndarray,
                  probe_slots: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Numpy fallback — same contract as ``ref.hash_probe_ref``."""
    table_size = len(table_start)
    slots = probe_slots.astype(np.int64, copy=False)
    ok = (slots >= 0) & (slots < table_size)
    idx = np.where(ok, slots, 0)
    if table_size == 0:
        z = np.zeros(len(probe_slots), np.int32)
        return z, z.copy()
    starts = np.where(ok, table_start[idx], 0).astype(np.int32)
    counts = np.where(ok, table_count[idx], 0).astype(np.int32)
    return starts, counts


def masked_hash_probe_np(table_start: np.ndarray,
                         table_count: np.ndarray,
                         probe_slots: np.ndarray,
                         probe_mask: np.ndarray
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Numpy fallback — same contract as ``ref.masked_hash_probe_ref``:
    lanes with a falsy mask emit (0, 0)."""
    starts, counts = hash_probe_np(table_start, table_count,
                                   probe_slots)
    keep = probe_mask.astype(bool, copy=False)
    zero = np.int32(0)
    return (np.where(keep, starts, zero).astype(np.int32),
            np.where(keep, counts, zero).astype(np.int32))


def build_probe_table_np(slots_sorted: np.ndarray, table_size: int
                         ) -> tuple[np.ndarray, np.ndarray]:
    """Numpy build — same contract as ``ref.build_probe_table``."""
    s = slots_sorted.astype(np.int64, copy=False)
    in_range = (s >= 0) & (s < table_size)
    counts = np.bincount(s[in_range], minlength=table_size
                         ).astype(np.int32)
    starts = np.concatenate([np.zeros(1, np.int32),
                             np.cumsum(counts)[:-1].astype(np.int32)])
    return starts, counts


@functools.lru_cache(maxsize=None)
def _jitted(use_pallas: bool, block_n: int, block_t: int,
            interpret: bool):
    import jax

    from repro.kernels.hash_join.kernel import hash_probe_kernel
    from repro.kernels.hash_join.ref import hash_probe_ref

    def probe(table_start, table_count, probe_slots):
        if not use_pallas:
            return hash_probe_ref(table_start, table_count, probe_slots)
        return hash_probe_kernel(table_start, table_count, probe_slots,
                                 block_n=block_n, block_t=block_t,
                                 interpret=interpret)

    return jax.jit(probe)


def hash_probe(table_start, table_count, probe_slots, *,
               use_pallas: bool = False, block_n: int = 256,
               block_t: int = 512, interpret: bool = True):
    """Per-probe-lane (start, count) into the slot-sorted build array.

    Accepts jax arrays (traced or concrete) or numpy arrays; numpy
    inputs without an importable JAX take :func:`hash_probe_np` — the
    shared fallback path of ``kernels.fallback``.
    """
    if isinstance(probe_slots, np.ndarray):
        try:
            import jax  # noqa: F401
        except ImportError:
            return hash_probe_np(np.asarray(table_start),
                                 np.asarray(table_count), probe_slots)
    return _jitted(use_pallas, block_n, block_t, interpret)(
        table_start, table_count, probe_slots)


@functools.lru_cache(maxsize=None)
def _jitted_masked(use_pallas: bool, block_n: int, block_t: int,
                   interpret: bool):
    import jax

    from repro.kernels.hash_join.kernel import masked_hash_probe_kernel
    from repro.kernels.hash_join.ref import masked_hash_probe_ref

    def probe(table_start, table_count, probe_slots, probe_mask):
        if not use_pallas:
            return masked_hash_probe_ref(table_start, table_count,
                                         probe_slots, probe_mask)
        return masked_hash_probe_kernel(
            table_start, table_count, probe_slots, probe_mask,
            block_n=block_n, block_t=block_t, interpret=interpret)

    return jax.jit(probe)


def masked_hash_probe(table_start, table_count, probe_slots,
                      probe_mask, *, use_pallas: bool = False,
                      block_n: int = 256, block_t: int = 512,
                      interpret: bool = True):
    """Filter-fused probe: :func:`hash_probe` with a per-lane keep
    mask; masked-out lanes emit (0, 0). Same dispatch ladder (XLA
    oracle / Pallas kernel / numpy floor)."""
    if isinstance(probe_slots, np.ndarray):
        try:
            import jax  # noqa: F401
        except ImportError:
            return masked_hash_probe_np(
                np.asarray(table_start), np.asarray(table_count),
                probe_slots, np.asarray(probe_mask))
    return _jitted_masked(use_pallas, block_n, block_t, interpret)(
        table_start, table_count, probe_slots, probe_mask)
