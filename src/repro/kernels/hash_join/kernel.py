"""Hash-probe Pallas TPU kernel (the hash-join inner loop).

Probes an open-addressing build table — (start, count) slot arrays in
VMEM; ``ref.build_probe_table`` documents the canonical sorted-side
construction, and ``exec.sharded.probe_table`` builds the equivalent
arrival-order variant inline under ``shard_map`` — for a block of
probe lanes at a time. TPU Pallas has no vector gather from VMEM, so
the lookup is realized the same way the segment-sum kernel scatters:
tile the table over the minor grid dimension and one-hot-reduce each
table tile against the probe lanes' target slots. A lane's slot falls
in exactly one tile (the hash is perfect over dense codes — see
ref.py), so summing the masked contributions across table tiles IS
the gather.

Tiling: grid = (n_probe_tiles, n_table_tiles), table minor
(sequential), so each probe tile's output block is revisited across
table steps and carries the accumulated (start, count) — the same
carried-accumulator pattern as the segment-sum kernel. All inputs are
reshaped to 2D (TPU-friendly; 1D iota is illegal on TPU — the guide's
broadcasted_iota rule). Invalid lanes (slot outside [0, table_size):
NULL/NaN keys, other shards' ranges, padding) match no tile and emit
count 0 — the masked probe.

VMEM at (block_n=256, block_t=512), int32: slots 1KB + table slabs
2·2KB + one-hot int32 512KB + out 2·1KB ≈ 0.52MB « 16MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_body(slot_ref, ts_ref, tc_ref, start_ref, cnt_ref, *,
                block_n: int, block_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        start_ref[...] = jnp.zeros_like(start_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    slots = slot_ref[0, :]                   # (block_n,)
    local = slots - ti * block_t             # slot within this table tile
    # one-hot lookup mask: probe lane i reads table column j iff its
    # slot lands on j in this tile. 2D iota per the TPU guide.
    col = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_t), 1)
    onehot = ((col == local[:, None])
              & (local >= 0)[:, None]
              & (local < block_t)[:, None])
    zero = jnp.zeros((), jnp.int32)
    # dtype pinned: under an ambient jax_enable_x64 scope jnp.sum
    # would otherwise accumulate int64 and fail the int32 ref store.
    start_ref[0, :] += jnp.sum(
        jnp.where(onehot, ts_ref[0, :][None, :], zero), axis=1,
        dtype=jnp.int32)
    cnt_ref[0, :] += jnp.sum(
        jnp.where(onehot, tc_ref[0, :][None, :], zero), axis=1,
        dtype=jnp.int32)


def _masked_probe_body(slot_ref, mask_ref, ts_ref, tc_ref, start_ref,
                       cnt_ref, *, block_n: int, block_t: int):
    """Filter-fused variant: a lane whose mask is 0 matches no table
    column, so its (start, count) stays at the zero-init — the filtered
    row never leaves VMEM (no host-side mask application, no
    intermediate filtered copy)."""
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        start_ref[...] = jnp.zeros_like(start_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    slots = slot_ref[0, :]
    keep = mask_ref[0, :] != 0
    local = slots - ti * block_t
    col = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_t), 1)
    onehot = ((col == local[:, None])
              & (local >= 0)[:, None]
              & (local < block_t)[:, None]
              & keep[:, None])
    zero = jnp.zeros((), jnp.int32)
    start_ref[0, :] += jnp.sum(
        jnp.where(onehot, ts_ref[0, :][None, :], zero), axis=1,
        dtype=jnp.int32)
    cnt_ref[0, :] += jnp.sum(
        jnp.where(onehot, tc_ref[0, :][None, :], zero), axis=1,
        dtype=jnp.int32)


def hash_probe_kernel(table_start, table_count, probe_slots, *,
                      block_n: int = 256, block_t: int = 512,
                      interpret: bool = True):
    """probe_slots: (n,) int32; table_start/table_count: (T,) int32.

    Pads n to a block_n multiple (padding lanes get slot -1, i.e.
    masked) and T to a block_t multiple (empty slots carry count 0).
    Returns (starts (n,) int32, counts (n,) int32) — bit-identical to
    ``ref.hash_probe_ref``.
    """
    n = probe_slots.shape[0]
    t = table_start.shape[0]
    block_n = max(1, min(block_n, n)) if n else 1
    block_t = max(1, min(block_t, t)) if t else 1
    pad_n = (-n) % block_n if n else block_n
    if pad_n:
        probe_slots = jnp.pad(probe_slots, (0, pad_n),
                              constant_values=-1)
    pad_t = (-t) % block_t if t else block_t
    if pad_t:
        table_start = jnp.pad(table_start, (0, pad_t))
        table_count = jnp.pad(table_count, (0, pad_t))
    n_probe_tiles = probe_slots.shape[0] // block_n
    n_table_tiles = table_start.shape[0] // block_t

    s2 = probe_slots.astype(jnp.int32).reshape(n_probe_tiles, block_n)
    ts2 = table_start.astype(jnp.int32).reshape(n_table_tiles, block_t)
    tc2 = table_count.astype(jnp.int32).reshape(n_table_tiles, block_t)

    body = functools.partial(_probe_body, block_n=block_n,
                             block_t=block_t)
    starts, counts = pl.pallas_call(
        body,
        grid=(n_probe_tiles, n_table_tiles),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda p, ti: (p, 0)),
            pl.BlockSpec((1, block_t), lambda p, ti: (ti, 0)),
            pl.BlockSpec((1, block_t), lambda p, ti: (ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda p, ti: (p, 0)),
            pl.BlockSpec((1, block_n), lambda p, ti: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_probe_tiles, block_n), jnp.int32),
            jax.ShapeDtypeStruct((n_probe_tiles, block_n), jnp.int32),
        ],
        interpret=interpret,
    )(s2, ts2, tc2)
    return starts.reshape(-1)[:n], counts.reshape(-1)[:n]


def masked_hash_probe_kernel(table_start, table_count, probe_slots,
                             probe_mask, *, block_n: int = 256,
                             block_t: int = 512,
                             interpret: bool = True):
    """Filter-fused probe: lanes with ``probe_mask == 0`` emit (0, 0).

    Same tiling/padding contract as :func:`hash_probe_kernel` (padding
    lanes get mask 0 as well as slot -1 — doubly dead). Bit-identical
    to ``ref.masked_hash_probe_ref``.
    """
    n = probe_slots.shape[0]
    t = table_start.shape[0]
    block_n = max(1, min(block_n, n)) if n else 1
    block_t = max(1, min(block_t, t)) if t else 1
    pad_n = (-n) % block_n if n else block_n
    if pad_n:
        probe_slots = jnp.pad(probe_slots, (0, pad_n),
                              constant_values=-1)
        probe_mask = jnp.pad(probe_mask.astype(jnp.int32), (0, pad_n))
    pad_t = (-t) % block_t if t else block_t
    if pad_t:
        table_start = jnp.pad(table_start, (0, pad_t))
        table_count = jnp.pad(table_count, (0, pad_t))
    n_probe_tiles = probe_slots.shape[0] // block_n
    n_table_tiles = table_start.shape[0] // block_t

    s2 = probe_slots.astype(jnp.int32).reshape(n_probe_tiles, block_n)
    m2 = probe_mask.astype(jnp.int32).reshape(n_probe_tiles, block_n)
    ts2 = table_start.astype(jnp.int32).reshape(n_table_tiles, block_t)
    tc2 = table_count.astype(jnp.int32).reshape(n_table_tiles, block_t)

    body = functools.partial(_masked_probe_body, block_n=block_n,
                             block_t=block_t)
    starts, counts = pl.pallas_call(
        body,
        grid=(n_probe_tiles, n_table_tiles),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda p, ti: (p, 0)),
            pl.BlockSpec((1, block_n), lambda p, ti: (p, 0)),
            pl.BlockSpec((1, block_t), lambda p, ti: (ti, 0)),
            pl.BlockSpec((1, block_t), lambda p, ti: (ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda p, ti: (p, 0)),
            pl.BlockSpec((1, block_n), lambda p, ti: (p, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_probe_tiles, block_n), jnp.int32),
            jax.ShapeDtypeStruct((n_probe_tiles, block_n), jnp.int32),
        ],
        interpret=interpret,
    )(s2, m2, ts2, tc2)
    return starts.reshape(-1)[:n], counts.reshape(-1)[:n]
