"""mLSTM chunkwise-parallel Pallas TPU kernel.

TPU adaptation (DESIGN.md §7): instead of CUDA's per-warp sequential
recurrence, chunks of L timesteps are processed in parallel on the MXU
(two (L×hd)·(hd×L)/(L×L)·(L×hd) matmuls per chunk) while the matrix
memory C (hd×hd), normalizer n (hd) and stabilizer m are carried across
chunks in VMEM scratch.

Grid: (B·H, n_chunks) — chunks minor ⇒ sequential state carry.
BlockSpecs stage (L, hd) q/k/v tiles and (1, L) gate rows in VMEM.
VMEM at L=256, hd=256: qkv 0.8MB + C 0.26MB + intra L×L 0.26MB ≈ 1.6MB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_body(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
                c_ref, n_ref, m_ref, *, chunk, hd):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    scale = 1.0 / math.sqrt(hd)
    q = q_ref[...].astype(jnp.float32) * scale     # (L, hd)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    log_i = li_ref[...].reshape(chunk)             # (L,)
    log_f = lf_ref[...].reshape(chunk)

    C0 = c_ref[...]                                # (hd, hd)
    n0 = n_ref[...].reshape(hd)
    m0 = m_ref[0, 0]

    F = jnp.cumsum(log_f)                          # (L,)
    m_intra = F[:, None] - F[None, :] + log_i[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m_intra = jnp.where(causal, m_intra, -1e30)
    m_state = F + m0                               # (L,)
    m_new = jnp.maximum(jnp.max(m_intra, axis=1), m_state)
    m_new = jnp.maximum(m_new, -1e30)
    d_intra = jnp.exp(m_intra - m_new[:, None])
    d_state = jnp.exp(m_state - m_new)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L,L)
    sd = s * d_intra
    intra = jax.lax.dot_general(sd, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    inter = jax.lax.dot_general(q, C0, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * d_state[:, None]
    num = intra + inter
    qn = (q @ n0) * d_state                        # (L,)
    den = jnp.abs(jnp.sum(sd, axis=1) + qn)
    den = jnp.maximum(den, jnp.exp(-m_new))
    o_ref[...] = (num / den[:, None]).astype(o_ref.dtype)

    # ---- carry state to end of chunk ----
    F_tot = F[chunk - 1]
    m1 = jnp.maximum(F_tot + m0, jnp.max(F_tot - F + log_i))
    w_state = jnp.exp(F_tot + m0 - m1)
    w_in = jnp.exp(F_tot - F + log_i - m1)         # (L,)
    kw = k * w_in[:, None]
    c_ref[...] = C0 * w_state + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = (n0 * w_state + jnp.sum(kw, axis=0)).reshape(1, hd)
    m_ref[...] = m1.reshape(1, 1)


def mlstm_chunkwise_kernel(q, k, v, log_i, log_f, *, chunk: int = 256,
                           interpret: bool = True) -> jax.Array:
    """q,k,v: (BH, S, hd); log_i/log_f: (BH, S) -> h (BH, S, hd)."""
    BH, S, hd = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    out = pl.pallas_call(
        functools.partial(_mlstm_body, chunk=chunk, hd=hd),
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((None, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((None, chunk), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((None, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, log_i, log_f)
    return out
