"""Pure-jnp oracle for the mLSTM kernel: exact sequential recurrence.

From arXiv:2405.04517, per head:
    m_t = max(log f_t + m_{t-1}, log i_t)
    i'  = exp(log i_t - m_t);  f' = exp(log f_t + m_{t-1} - m_t)
    C_t = f' C_{t-1} + i' k_t v_t^T
    n_t = f' n_{t-1} + i' k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, exp(-m_t))
with q scaled by 1/sqrt(hd).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mlstm_ref(q, k, v, log_i, log_f):
    """q,k,v: (BH, S, hd); log_i/log_f: (BH, S). Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    q = q.astype(jnp.float32) * scale
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        i_p = jnp.exp(li - m_new)
        f_p = jnp.exp(lf + m - m_new)
        C = f_p[:, None, None] * C + i_p[:, None, None] \
            * k_t[:, :, None] * v_t[:, None, :]
        n = f_p[:, None] * n + i_p[:, None] * k_t
        num = jnp.einsum("bde,bd->be", C, q_t)
        den = jnp.abs(jnp.einsum("bd,bd->b", n, q_t))
        den = jnp.maximum(den, jnp.exp(-m_new))
        h = num / den[:, None]
        return (C, n, m_new), h

    C0 = jnp.zeros((BH, hd, hd), jnp.float32)
    n0 = jnp.zeros((BH, hd), jnp.float32)
    m0 = jnp.zeros((BH,), jnp.float32)
    _, hs = jax.lax.scan(step, (C0, n0, m0),
                         (q.transpose(1, 0, 2), k.transpose(1, 0, 2),
                          v.transpose(1, 0, 2), log_i.T, log_f.T))
    return hs.transpose(1, 0, 2)
