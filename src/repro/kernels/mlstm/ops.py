"""Jit'd wrapper for the mLSTM chunkwise kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm.kernel import mlstm_chunkwise_kernel
from repro.kernels.mlstm.ref import mlstm_ref


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas",
                                             "interpret"))
def mlstm(q, k, v, log_i, log_f, *, chunk: int = 256,
          use_pallas: bool = True, interpret: bool = True):
    if not use_pallas:
        return mlstm_ref(q, k, v, log_i, log_f)
    return mlstm_chunkwise_kernel(q, k, v, log_i, log_f, chunk=chunk,
                                  interpret=interpret)
