"""Masked segment-sum Pallas TPU kernel (the GROUP BY SUM hot loop).

Tiling: grid = (n_seg_tiles, n_row_tiles) with the *row* dimension
minor (sequential), so each segment tile's accumulator lives in the
revisited output block across row steps — the same carried-accumulator
pattern as the flash-attention kernel's n_kv dimension. Inputs are
reshaped to (n_row_tiles, block_n) so every BlockSpec stays 2D
(TPU-friendly; 1D iota is illegal on TPU — the guide's broadcasted_iota
rule).

Per grid step the body scatters one (block_n,) slab of values into one
(block_s,) slab of segments via a one-hot mask + VPU reduction — no MXU
matmul, so integer sums stay exact (integer addition is associative
even under wraparound; only float sums are order-sensitive, covered by
tolerance in tests). Lanes outside [seg_start, seg_end), invalid lanes,
and row padding all fall out of the same one-hot mask.

VMEM at (block_n=1024, block_s=512), f32: in slabs 3·4KB + one-hot
bool 512KB + out 2·2KB ≈ 0.53MB « 16MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.segment_sum.ref import reduce_identity


def _segsum_body(v_ref, id_ref, m_ref, sum_ref, cnt_ref, *,
                 block_n: int, block_s: int):
    si = pl.program_id(0)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    vals = v_ref[0, :]                       # (block_n,)
    ids = id_ref[0, :]
    msk = m_ref[0, :] != 0
    local = ids - si * block_s               # segment id within this tile
    # one-hot scatter mask: lane i contributes to segment column j iff
    # its (valid, in-tile) id equals j. 2D iota per the TPU guide.
    seg = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_s), 1)
    onehot = ((seg == local[:, None])
              & msk[:, None]
              & (local >= 0)[:, None]
              & (local < block_s)[:, None])
    zero = jnp.zeros((), sum_ref.dtype)
    contrib = jnp.where(onehot, vals[:, None].astype(sum_ref.dtype),
                        zero)
    sum_ref[0, :] += jnp.sum(contrib, axis=0)
    cnt_ref[0, :] += jnp.sum(onehot.astype(jnp.int32), axis=0)


def masked_segment_sum_kernel(values, segment_ids, valid,
                              num_segments: int, *,
                              block_n: int = 1024, block_s: int = 512,
                              interpret: bool = True):
    """values: (n,); segment_ids: (n,) int32; valid: (n,) bool.

    Pads n to a block_n multiple (padding lanes masked invalid) and
    num_segments to a block_s multiple (sliced off on return).
    Returns (sums (num_segments,) values.dtype, counts (num_segments,)
    int32).
    """
    n = values.shape[0]
    block_n = max(1, min(block_n, n)) if n else 1
    block_s = max(1, min(block_s, num_segments))
    pad_n = (-n) % block_n if n else block_n
    if pad_n:
        values = jnp.pad(values, (0, pad_n))
        segment_ids = jnp.pad(segment_ids, (0, pad_n))
        valid = jnp.pad(valid, (0, pad_n))   # False: padding is masked
    s_pad = ((num_segments + block_s - 1) // block_s) * block_s
    n_row_tiles = values.shape[0] // block_n
    n_seg_tiles = s_pad // block_s

    v2 = values.reshape(n_row_tiles, block_n)
    id2 = segment_ids.astype(jnp.int32).reshape(n_row_tiles, block_n)
    m2 = valid.astype(jnp.int32).reshape(n_row_tiles, block_n)

    body = functools.partial(_segsum_body, block_n=block_n,
                             block_s=block_s)
    sums, counts = pl.pallas_call(
        body,
        grid=(n_seg_tiles, n_row_tiles),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda s, r: (r, 0)),
            pl.BlockSpec((1, block_n), lambda s, r: (r, 0)),
            pl.BlockSpec((1, block_n), lambda s, r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s), lambda s, r: (s, 0)),
            pl.BlockSpec((1, block_s), lambda s, r: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_seg_tiles, block_s), values.dtype),
            jax.ShapeDtypeStruct((n_seg_tiles, block_s), jnp.int32),
        ],
        interpret=interpret,
    )(v2, id2, m2)
    return (sums.reshape(-1)[:num_segments],
            counts.reshape(-1)[:num_segments])


def _segreduce_body(v_ref, id_ref, m_ref, red_ref, cnt_ref, nan_ref, *,
                    block_n: int, block_s: int, op: str, ident):
    si = pl.program_id(0)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        red_ref[...] = jnp.full_like(red_ref, ident)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        nan_ref[...] = jnp.zeros_like(nan_ref)

    vals = v_ref[0, :]                       # (block_n,)
    ids = id_ref[0, :]
    msk = m_ref[0, :] != 0
    isnan = vals != vals                     # all-False for int dtypes
    local = ids - si * block_s
    seg = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_s), 1)
    onehot = ((seg == local[:, None])
              & msk[:, None]
              & (local >= 0)[:, None]
              & (local < block_s)[:, None])
    idv = jnp.asarray(ident, red_ref.dtype)
    # NaN lanes are parked at the identity here; the wrapper re-poisons
    # their segments from nan_ref so min/max stay a clean VPU reduce.
    contrib = jnp.where(onehot & (~isnan)[:, None],
                        vals[:, None].astype(red_ref.dtype), idv)
    if op == "min":
        red_ref[0, :] = jnp.minimum(red_ref[0, :],
                                    jnp.min(contrib, axis=0))
    else:
        red_ref[0, :] = jnp.maximum(red_ref[0, :],
                                    jnp.max(contrib, axis=0))
    cnt_ref[0, :] += jnp.sum(onehot.astype(jnp.int32), axis=0)
    nan_ref[0, :] += jnp.sum((onehot & isnan[:, None]).astype(jnp.int32),
                             axis=0)


def masked_segment_reduce_kernel(values, segment_ids, valid,
                                 num_segments: int, op: str, *,
                                 block_n: int = 1024, block_s: int = 512,
                                 interpret: bool = True):
    """Tiled Pallas masked segment MIN/MAX — segment-sum's tiling, an
    identity-initialised carried accumulator, and a NaN-count output so
    float NaN propagation matches the host backends bit-for-bit.

    Returns (reduced (num_segments,) values.dtype, counts int32).
    """
    ident = reduce_identity(values.dtype, op)
    n = values.shape[0]
    block_n = max(1, min(block_n, n)) if n else 1
    block_s = max(1, min(block_s, num_segments))
    pad_n = (-n) % block_n if n else block_n
    if pad_n:
        values = jnp.pad(values, (0, pad_n))
        segment_ids = jnp.pad(segment_ids, (0, pad_n))
        valid = jnp.pad(valid, (0, pad_n))   # False: padding is masked
    s_pad = ((num_segments + block_s - 1) // block_s) * block_s
    n_row_tiles = values.shape[0] // block_n
    n_seg_tiles = s_pad // block_s

    v2 = values.reshape(n_row_tiles, block_n)
    id2 = segment_ids.astype(jnp.int32).reshape(n_row_tiles, block_n)
    m2 = valid.astype(jnp.int32).reshape(n_row_tiles, block_n)

    body = functools.partial(_segreduce_body, block_n=block_n,
                             block_s=block_s, op=op, ident=ident)
    red, counts, nans = pl.pallas_call(
        body,
        grid=(n_seg_tiles, n_row_tiles),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda s, r: (r, 0)),
            pl.BlockSpec((1, block_n), lambda s, r: (r, 0)),
            pl.BlockSpec((1, block_n), lambda s, r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s), lambda s, r: (s, 0)),
            pl.BlockSpec((1, block_s), lambda s, r: (s, 0)),
            pl.BlockSpec((1, block_s), lambda s, r: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_seg_tiles, block_s), values.dtype),
            jax.ShapeDtypeStruct((n_seg_tiles, block_s), jnp.int32),
            jax.ShapeDtypeStruct((n_seg_tiles, block_s), jnp.int32),
        ],
        interpret=interpret,
    )(v2, id2, m2)
    red = red.reshape(-1)[:num_segments]
    counts = counts.reshape(-1)[:num_segments]
    nans = nans.reshape(-1)[:num_segments]
    if jnp.issubdtype(values.dtype, jnp.floating):
        red = jnp.where(nans > 0, jnp.asarray(jnp.nan, values.dtype),
                        red)
    return red, counts
