"""Jit'd public wrapper: XLA segment_sum or the Pallas kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.segment_sum.kernel import masked_segment_sum_kernel
from repro.kernels.segment_sum.ref import masked_segment_sum_ref


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "use_pallas", "block_n", "block_s", "interpret"))
def masked_segment_sum(values, segment_ids, valid, num_segments: int, *,
                       use_pallas: bool = False,
                       block_n: int = 1024, block_s: int = 512,
                       interpret: bool = True):
    """Per-segment SUM over valid lanes + valid-lane counts.

    ``use_pallas=False`` (default) lowers to XLA's scatter-add
    (``jax.ops.segment_sum``); ``use_pallas=True`` runs the tiled
    Pallas kernel (``interpret=True`` on CPU containers — TPU is the
    compile target). Both return (sums values.dtype, counts int32).
    """
    if not use_pallas:
        return masked_segment_sum_ref(values, segment_ids, valid,
                                      num_segments)
    return masked_segment_sum_kernel(
        values, segment_ids, valid, num_segments,
        block_n=block_n, block_s=block_s, interpret=interpret)
