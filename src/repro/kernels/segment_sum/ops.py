"""Jit'd public wrappers: XLA segment ops or the Pallas kernels."""
from __future__ import annotations

import functools

import jax

from repro.kernels.segment_sum.kernel import (masked_segment_reduce_kernel,
                                              masked_segment_sum_kernel)
from repro.kernels.segment_sum.ref import (masked_segment_reduce_ref,
                                           masked_segment_sum_ref)


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "use_pallas", "block_n", "block_s", "interpret"))
def masked_segment_sum(values, segment_ids, valid, num_segments: int, *,
                       use_pallas: bool = False,
                       block_n: int = 1024, block_s: int = 512,
                       interpret: bool = True):
    """Per-segment SUM over valid lanes + valid-lane counts.

    ``use_pallas=False`` (default) lowers to XLA's scatter-add
    (``jax.ops.segment_sum``); ``use_pallas=True`` runs the tiled
    Pallas kernel (``interpret=True`` on CPU containers — TPU is the
    compile target). Both return (sums values.dtype, counts int32).
    """
    if not use_pallas:
        return masked_segment_sum_ref(values, segment_ids, valid,
                                      num_segments)
    return masked_segment_sum_kernel(
        values, segment_ids, valid, num_segments,
        block_n=block_n, block_s=block_s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "op", "use_pallas", "block_n", "block_s",
    "interpret"))
def masked_segment_reduce(values, segment_ids, valid, num_segments: int,
                          *, op: str, use_pallas: bool = False,
                          block_n: int = 1024, block_s: int = 512,
                          interpret: bool = True):
    """Per-segment MIN/MAX over valid lanes + valid-lane counts.

    ``op`` is ``"min"`` or ``"max"``; NaN in a valid float lane poisons
    its segment, empty segments return the identity (NULL upstream).
    Same XLA-vs-Pallas switch as :func:`masked_segment_sum`.
    """
    if op not in ("min", "max"):
        raise ValueError(f"unknown segment reduce op: {op!r}")
    if not use_pallas:
        return masked_segment_reduce_ref(values, segment_ids, valid,
                                         num_segments, op)
    return masked_segment_reduce_kernel(
        values, segment_ids, valid, num_segments, op,
        block_n=block_n, block_s=block_s, interpret=interpret)
