"""Pure-jnp oracle for the masked segment-sum kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_segment_sum_ref(values, segment_ids, valid,
                           num_segments: int):
    """Per-segment SUM over valid lanes + per-segment valid-lane counts.

    values: (n,) numeric; segment_ids: (n,) int32 in [0, num_segments);
    valid: (n,) bool. Returns (sums (num_segments,) values.dtype,
    counts (num_segments,) int32). SQL SUM semantics live one level up:
    a segment with count 0 is a NULL sum (the caller masks it).
    """
    masked = jnp.where(valid, values, jnp.zeros((), values.dtype))
    sums = jax.ops.segment_sum(masked, segment_ids,
                               num_segments=num_segments)
    counts = jax.ops.segment_sum(valid.astype(jnp.int32), segment_ids,
                                 num_segments=num_segments)
    return sums, counts
