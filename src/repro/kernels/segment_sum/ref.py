"""Pure-jnp oracles for the masked segment-reduce kernel family."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def reduce_identity(dtype, op: str):
    """Identity element for a masked segment MIN/MAX over ``dtype``.

    Invalid (and NaN) lanes are replaced with this value before the
    reduction so they cannot win; an all-identity segment is a NULL
    result (the caller masks it via the counts output).
    """
    dtype = np.dtype(dtype)
    if dtype.kind == "f":
        return dtype.type(np.inf if op == "min" else -np.inf)
    info = np.iinfo(dtype)
    return dtype.type(info.max if op == "min" else info.min)


def masked_segment_sum_ref(values, segment_ids, valid,
                           num_segments: int):
    """Per-segment SUM over valid lanes + per-segment valid-lane counts.

    values: (n,) numeric; segment_ids: (n,) int32 in [0, num_segments);
    valid: (n,) bool. Returns (sums (num_segments,) values.dtype,
    counts (num_segments,) int32). SQL SUM semantics live one level up:
    a segment with count 0 is a NULL sum (the caller masks it).
    """
    masked = jnp.where(valid, values, jnp.zeros((), values.dtype))
    sums = jax.ops.segment_sum(masked, segment_ids,
                               num_segments=num_segments)
    counts = jax.ops.segment_sum(valid.astype(jnp.int32), segment_ids,
                                 num_segments=num_segments)
    return sums, counts


def masked_segment_reduce_ref(values, segment_ids, valid,
                              num_segments: int, op: str):
    """Per-segment MIN/MAX over valid lanes + valid-lane counts.

    ``op`` is ``"min"`` or ``"max"``. A NaN in a *valid* lane poisons
    its whole segment (numpy ``minimum``/``maximum`` semantics, matched
    bit-for-bit by the host backends); invalid lanes never contribute.
    Segments with count 0 return the identity — NULL at the SQL layer.
    Returns (reduced (num_segments,) values.dtype, counts int32).
    """
    ident = reduce_identity(values.dtype, op)
    isnan = values != values                 # all-False for int dtypes
    clean = jnp.where(valid & ~isnan, values, ident)
    fn = jax.ops.segment_min if op == "min" else jax.ops.segment_max
    red = fn(clean, segment_ids, num_segments=num_segments)
    counts = jax.ops.segment_sum(valid.astype(jnp.int32), segment_ids,
                                 num_segments=num_segments)
    if jnp.issubdtype(values.dtype, jnp.floating):
        nans = jax.ops.segment_sum((valid & isnan).astype(jnp.int32),
                                   segment_ids,
                                   num_segments=num_segments)
        red = jnp.where(nans > 0, jnp.asarray(jnp.nan, values.dtype),
                        red)
    red = jnp.where(counts > 0, red, ident)
    return red, counts
