"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """q: (B,H,Sq,hd), k/v: (B,H,Skv,hd) (same head count). float32 math."""
    B, H, Sq, hd = q.shape
    Skv = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
