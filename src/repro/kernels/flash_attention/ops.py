"""Jit'd public wrapper: GQA folding + dispatch to kernel or XLA path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    block_q: int = 128, block_kv: int = 256,
                    use_pallas: bool = True,
                    interpret: bool = True) -> jax.Array:
    """Multi-head attention. q: (B,H,Sq,hd); k/v: (B,K,Skv,hd), K | H.

    GQA is handled by broadcasting kv heads before folding (B,H) into the
    kernel's batch-of-heads dimension.
    """
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    Skv = k.shape[2]
    if not use_pallas:
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * H, Skv, hd)
    vf = v.reshape(B * H, Skv, hd)
    out = flash_attention_kernel(qf, kf, vf, causal=causal, window=window,
                                 block_q=block_q, block_kv=block_kv,
                                 interpret=interpret)
    return out.reshape(B, H, Sq, hd)
