"""Flash attention Pallas TPU kernel (GQA / causal / sliding-window).

Tiling (DESIGN.md §7): grid = (B·H, n_q, n_kv); BlockSpecs stage one
(block_q × hd) q tile and one (block_kv × hd) k/v tile in VMEM per grid
step; the online-softmax accumulators (o, m, l) live in VMEM scratch and
are carried across the n_kv (minor, sequential) grid dimension. Default
block sizes are 128/256 — multiples of the 128-lane MXU tiles.

Causal / windowed tiles that are fully masked are skipped via ``pl.when``
(no MXU work issued), matching the trace-time tile skipping of the
pure-XLA reference path (`repro.models.layers.blockwise_attention`).

VMEM budget at (block_q=128, block_kv=256, hd=128), bf16 in / f32 acc:
q 32KB + k/v 128KB + s/p 128KB + acc 64KB ≈ 0.4MB « 16MB VMEM — leaves
room for double-buffered HBM→VMEM prefetch of the next k/v tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _fa_body(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
             block_q, block_kv, causal, window, scale, n_kv, sq, skv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_kv

    # tile-level skip predicate (mirrors _tile_pairs in the XLA path)
    live = k_start < skv
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window is not None:
        live &= k_start + block_kv - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[...].astype(jnp.float32)            # (block_q, hd)
        k = k_ref[...].astype(jnp.float32)            # (block_kv, hd)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_kv), 1)
        mask = kpos < skv
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, _NEG_INF)
        m_t = jnp.max(s, axis=1)                       # (bq,)
        m_old = m_ref[...]
        m_new = jnp.maximum(m_old, m_t)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_old - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           block_q: int = 128, block_kv: int = 256,
                           interpret: bool = True) -> jax.Array:
    """q,k,v: (BH, S, hd) with identical head counts (GQA folded by ops).

    Pads S to block multiples; masks padding inside the kernel.
    """
    BH, sq, hd = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0)))
    n_q = q.shape[1] // block_q
    n_kv = k.shape[1] // block_kv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _fa_body, block_q=block_q, block_kv=block_kv, causal=causal,
        window=window, scale=scale, n_kv=n_kv, sq=sq, skv=skv)

    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((None, block_kv, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((None, block_kv, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd),
                               lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :]
